//! Network layer graph: the manifest-driven layer table the selection
//! pipeline operates on.
//!
//! Responsibilities (paper §3.4.1 "Setting layer precision"):
//!  * per-layer computational cost (MACs → BMACs under a bit-width);
//!  * **linked layers** — layers whose activations feed the same consumer
//!    must share precision (e.g. a residual downsample conv and the block
//!    conv joining the same ReLU).  Linked layers form one knapsack item
//!    whose cost/gain is the sum over members (paper Fig. 9 caption);
//!  * fixed-precision rules — first/last layers at 8-bit; such layers are
//!    excluded from the budget (they contribute no selectable BMACs).

use std::path::Path;

use crate::jsonio::Json;

/// One row of the manifest layer table.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub kind: String,
    /// Index into the runtime `bits` vector.
    pub qindex: usize,
    pub link_group: String,
    pub macs: u64,
    pub weight_params: u64,
    /// `Some(b)` → pinned at b bits, excluded from selection and budget.
    pub fixed_bits: Option<u32>,
}

/// A selectable knapsack item: one or more linked layers.
#[derive(Debug, Clone)]
pub struct Group {
    pub name: String,
    pub layer_idx: Vec<usize>,
    /// Σ MACs over member layers.
    pub macs: u64,
}

/// The layer graph of one model.
#[derive(Debug, Clone)]
pub struct Graph {
    pub model: String,
    pub layers: Vec<Layer>,
    /// Selectable link groups only (fixed layers excluded), in topological
    /// order of their first member.
    pub groups: Vec<Group>,
}

impl Graph {
    pub fn from_manifest(manifest: &Json) -> crate::Result<Graph> {
        let model = manifest
            .at(&["model"])
            .as_str()
            .ok_or_else(|| crate::err!("manifest missing model name"))?
            .to_string();
        let rows = manifest
            .at(&["layers"])
            .as_arr()
            .ok_or_else(|| crate::err!("manifest missing layers"))?;
        let mut layers = Vec::with_capacity(rows.len());
        for row in rows {
            layers.push(Layer {
                name: row.at(&["name"]).as_str().unwrap_or_default().to_string(),
                kind: row.at(&["kind"]).as_str().unwrap_or_default().to_string(),
                qindex: row
                    .at(&["qindex"])
                    .as_usize()
                    .ok_or_else(|| crate::err!("layer missing qindex"))?,
                link_group: row
                    .at(&["link_group"])
                    .as_str()
                    .unwrap_or_default()
                    .to_string(),
                macs: row.at(&["macs"]).as_f64().unwrap_or(0.0) as u64,
                weight_params: row.at(&["weight_params"]).as_f64().unwrap_or(0.0) as u64,
                fixed_bits: row.at(&["fixed_bits"]).as_f64().map(|b| b as u32),
            });
        }
        // Build selectable groups preserving first-appearance order.
        let mut groups: Vec<Group> = Vec::new();
        for (i, layer) in layers.iter().enumerate() {
            if layer.fixed_bits.is_some() {
                continue;
            }
            match groups.iter_mut().find(|g| g.name == layer.link_group) {
                Some(g) => {
                    g.layer_idx.push(i);
                    g.macs += layer.macs;
                }
                None => groups.push(Group {
                    name: layer.link_group.clone(),
                    layer_idx: vec![i],
                    macs: layer.macs,
                }),
            }
        }
        Ok(Graph {
            model,
            layers,
            groups,
        })
    }

    pub fn load(artifacts: &Path, model: &str) -> crate::Result<Graph> {
        let path = crate::backend::manifest::manifest_path_checked(artifacts, model)?;
        let manifest = crate::jsonio::parse_file(&path)?;
        Graph::from_manifest(&manifest)
    }

    /// Number of entries in the runtime bits vector.
    pub fn n_bits(&self) -> usize {
        self.layers.len()
    }

    /// Total *selectable* BMACs when every selectable group runs at `b`.
    /// Fixed layers do not count toward the budget (paper §3.4.1).
    pub fn selectable_bmacs(&self, b: u32) -> u64 {
        self.groups.iter().map(|g| g.macs * b as u64).sum()
    }

    /// Budget in BMACs at a fraction of the all-`b_hi` cost.  The paper
    /// samples budgets between the 4-bit (100%) and 2-bit (50%) costs.
    pub fn budget_at(&self, fraction: f64, b_hi: u32) -> u64 {
        (self.selectable_bmacs(b_hi) as f64 * fraction).round() as u64
    }

    /// Per-group extra BMAC cost of staying at `b_hi` instead of `b_lo` —
    /// the knapsack item weight (§3.1).
    pub fn group_weights(&self, b_hi: u32, b_lo: u32) -> Vec<u64> {
        self.groups
            .iter()
            .map(|g| g.macs * (b_hi - b_lo) as u64)
            .collect()
    }

    /// Aggregate per-layer values over link groups (gain estimates are
    /// produced per layer; the knapsack item value is the sum over
    /// members, §3.4.1).
    pub fn aggregate_by_group(&self, per_layer: &[f64]) -> Vec<f64> {
        self.groups
            .iter()
            .map(|g| g.layer_idx.iter().map(|&i| per_layer[self.layers[i].qindex]).sum())
            .collect()
    }

    /// The knapsack base cost: all selectable groups at `b_lo` (this part
    /// is spent regardless of selection).
    pub fn base_bmacs(&self, b_lo: u32) -> u64 {
        self.groups.iter().map(|g| g.macs * b_lo as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;

    fn toy_manifest() -> Json {
        jsonio::parse(
            r#"{
          "model": "toy",
          "layers": [
            {"name":"stem","kind":"conv","qindex":0,"link_group":"stem",
             "macs":1000,"weight_params":100,"fixed_bits":8},
            {"name":"a","kind":"conv","qindex":1,"link_group":"a",
             "macs":2000,"weight_params":200,"fixed_bits":null},
            {"name":"b","kind":"conv","qindex":2,"link_group":"ab",
             "macs":3000,"weight_params":300,"fixed_bits":null},
            {"name":"b_down","kind":"conv","qindex":3,"link_group":"ab",
             "macs":500,"weight_params":50,"fixed_bits":null},
            {"name":"head","kind":"linear","qindex":4,"link_group":"head",
             "macs":100,"weight_params":10,"fixed_bits":8}
          ]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn groups_exclude_fixed_and_merge_links() {
        let g = Graph::from_manifest(&toy_manifest()).unwrap();
        assert_eq!(g.groups.len(), 2);
        assert_eq!(g.groups[0].name, "a");
        assert_eq!(g.groups[1].name, "ab");
        assert_eq!(g.groups[1].layer_idx.len(), 2);
        assert_eq!(g.groups[1].macs, 3500);
    }

    #[test]
    fn budgets_and_weights() {
        let g = Graph::from_manifest(&toy_manifest()).unwrap();
        // selectable MACs = 2000 + 3500 = 5500 → 4-bit BMACs = 22000.
        assert_eq!(g.selectable_bmacs(4), 22_000);
        assert_eq!(g.budget_at(0.5, 4), 11_000); // == all-2-bit cost
        assert_eq!(g.group_weights(4, 2), vec![4000, 7000]);
        assert_eq!(g.base_bmacs(2), 11_000);
    }

    #[test]
    fn group_aggregation() {
        let g = Graph::from_manifest(&toy_manifest()).unwrap();
        let per_layer = vec![9.0, 1.0, 2.0, 3.0, 9.0]; // by qindex
        assert_eq!(g.aggregate_by_group(&per_layer), vec![1.0, 5.0]);
    }
}
