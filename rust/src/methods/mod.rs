//! Layer-precision-selection methods: the paper's two contributions (EAGL,
//! ALPS) plus every comparator the evaluation framework ranks them against
//! (§4: HAWQ-v3 re-implementation, uniform-gain, first-to-last,
//! last-to-first, and the Appendix-B regression oracle).
//!
//! All methods produce a per-layer gain estimate `G_l` (or, for the
//! topological baselines, a drop order) and share the same downstream
//! pipeline: group-aggregate → 0-1 knapsack under a BMAC budget →
//! mixed-precision checkpoint transform → LSQ fine-tune.

use std::time::Instant;

use crate::ckpt::Checkpoint;
use crate::coordinator::job_pool;
use crate::data::Dataset;
use crate::eagl;
use crate::graph::Graph;
use crate::knapsack::{self, Selection};
use crate::quant::{self, BitsConfig};
use crate::backend::{Backend, BackendFactory, Task, TrainState};
use crate::train::{finetune, TrainConfig};

/// The selection methods under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MethodKind {
    /// Entropy Approximation Guided Layer selection (§3.3, ours).
    Eagl,
    /// Accuracy-aware Layer Precision Selection (§3.2, ours).
    Alps,
    /// Hessian-trace × quantization-error (Appendix C re-implementation).
    HawqV3,
    /// Every layer gets the same gain (knapsack fills by cost alone).
    Uniform,
    /// Drop layers first→last in topological order until budget met.
    FirstToLast,
    /// Drop layers last→first.
    LastToFirst,
    /// Externally supplied gains (Appendix B regression coefficients).
    Oracle,
}

impl MethodKind {
    pub fn name(self) -> &'static str {
        match self {
            MethodKind::Eagl => "eagl",
            MethodKind::Alps => "alps",
            MethodKind::HawqV3 => "hawq_v3",
            MethodKind::Uniform => "uniform",
            MethodKind::FirstToLast => "first_to_last",
            MethodKind::LastToFirst => "last_to_first",
            MethodKind::Oracle => "oracle",
        }
    }

    pub fn parse(s: &str) -> crate::Result<MethodKind> {
        Ok(match s {
            "eagl" => MethodKind::Eagl,
            "alps" => MethodKind::Alps,
            "hawq_v3" | "hawq" => MethodKind::HawqV3,
            "uniform" => MethodKind::Uniform,
            "first_to_last" | "f2l" => MethodKind::FirstToLast,
            "last_to_first" | "l2f" => MethodKind::LastToFirst,
            "oracle" => MethodKind::Oracle,
            other => crate::bail!("unknown method '{other}'"),
        })
    }

    /// Does this method produce per-layer gains (vs a pure drop order)?
    pub fn is_gain_based(self) -> bool {
        !matches!(self, MethodKind::FirstToLast | MethodKind::LastToFirst)
    }
}

/// Estimation hyperparameters (paper §3.2/§3.4.3 scaled to the testbed).
#[derive(Debug, Clone)]
pub struct MethodConfig {
    /// The higher / lower precision choices (4 / 2 throughout the paper).
    pub b_hi: u32,
    pub b_lo: u32,
    /// ALPS: steps of the per-layer "one epoch" fine-tune.
    pub alps_steps: usize,
    pub alps_lr: f32,
    /// HAWQ: Hutchinson samples and data batches per sample.
    pub hawq_samples: usize,
    pub hawq_batches: usize,
    /// Gains for [`MethodKind::Oracle`].
    pub oracle_gains: Option<Vec<f64>>,
}

impl Default for MethodConfig {
    fn default() -> Self {
        MethodConfig {
            b_hi: 4,
            b_lo: 2,
            alps_steps: 40,
            alps_lr: 0.005,
            hawq_samples: 4,
            hawq_batches: 2,
            oracle_gains: None,
        }
    }
}

/// Outcome of gain estimation: per-layer gains (qindex order) + wall time
/// (the Table 3 measurement).
#[derive(Debug, Clone)]
pub struct GainEstimate {
    pub method: MethodKind,
    pub per_layer: Vec<f64>,
    pub wall_seconds: f64,
}

/// Estimate per-layer gains for a gain-based method.
///
/// `ckpt4` is the trained `b_hi`-bit checkpoint (Algorithm 1/2 both start
/// there); `data` feeds ALPS/HAWQ (EAGL never touches it — that asymmetry
/// *is* Table 3).
pub fn estimate_gains<B: Backend>(
    kind: MethodKind,
    rt: &mut B,
    graph: &Graph,
    ckpt4: &Checkpoint,
    data: &Dataset,
    cfg: &MethodConfig,
) -> crate::Result<GainEstimate> {
    crate::ensure!(kind.is_gain_based(), "{} has no gains", kind.name());
    let t0 = Instant::now();
    let per_layer = match dataless_gains(kind, graph, ckpt4, cfg) {
        Some(r) => r?,
        None => match kind {
            MethodKind::Alps => alps_gains(rt, graph, ckpt4, data, cfg)?,
            MethodKind::HawqV3 => hawq_gains(rt, graph, ckpt4, data, cfg)?,
            _ => unreachable!(),
        },
    };
    finish_estimate(kind, per_layer, graph, t0)
}

/// Gains for the methods that never touch a backend (EAGL's
/// checkpoint-only entropy is the paper's whole point); `None` for the
/// data-driven methods (ALPS/HAWQ).  Shared by the sequential and
/// parallel estimators so the arms cannot drift apart.
fn dataless_gains(
    kind: MethodKind,
    graph: &Graph,
    ckpt4: &Checkpoint,
    cfg: &MethodConfig,
) -> Option<crate::Result<Vec<f64>>> {
    Some(match kind {
        MethodKind::Eagl => eagl::checkpoint_entropies(graph, ckpt4, cfg.b_hi),
        MethodKind::Uniform => Ok(vec![1.0; graph.layers.len()]),
        MethodKind::Oracle => cfg
            .oracle_gains
            .clone()
            .ok_or_else(|| crate::err!("oracle gains not provided")),
        _ => return None,
    })
}

/// Validate and package a gain vector (shared wrapper tail).
fn finish_estimate(
    kind: MethodKind,
    per_layer: Vec<f64>,
    graph: &Graph,
    t0: Instant,
) -> crate::Result<GainEstimate> {
    crate::ensure!(
        per_layer.len() == graph.layers.len(),
        "gain vector length {} != layers {}",
        per_layer.len(),
        graph.layers.len()
    );
    Ok(GainEstimate {
        method: kind,
        per_layer,
        wall_seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Parallel variant of [`estimate_gains`]: ALPS per-group probes and
/// HAWQ Hutchinson draws are independent jobs, so they fan out over
/// [`job_pool`] with one factory-opened backend per worker.  The result
/// is **bit-identical** to the sequential path for any `workers` value:
/// each job is deterministic and backend-instance-independent, jobs are
/// fixed by the item list (not by scheduling), and the reductions run on
/// the pool's input-ordered results — asserted in
/// `rust/tests/kernel_cache_parallel.rs`.
///
/// `task` selects the ALPS signal (loss for segmentation, metric
/// otherwise) without opening an extra backend just to read a manifest.
#[allow(clippy::too_many_arguments)]
pub fn estimate_gains_parallel<F: BackendFactory>(
    kind: MethodKind,
    factory: &F,
    task: Task,
    graph: &Graph,
    ckpt4: &Checkpoint,
    data: &Dataset,
    cfg: &MethodConfig,
    workers: usize,
) -> crate::Result<GainEstimate> {
    crate::ensure!(kind.is_gain_based(), "{} has no gains", kind.name());
    let t0 = Instant::now();
    let per_layer = match dataless_gains(kind, graph, ckpt4, cfg) {
        Some(r) => r?,
        None => match kind {
            MethodKind::Alps => {
                alps_gains_parallel(factory, task, graph, ckpt4, data, cfg, workers)?
            }
            MethodKind::HawqV3 => hawq_gains_parallel(factory, graph, ckpt4, data, cfg, workers)?,
            _ => unreachable!(),
        },
    };
    finish_estimate(kind, per_layer, graph, t0)
}

/// One ALPS probe (Algorithm 1, one group): drop group `g` to `b_lo`,
/// fine-tune briefly from `ckpt4`, return the train signal.  Fully
/// determined by its arguments — safe to run on any backend instance.
fn alps_probe<B: Backend>(
    rt: &mut B,
    graph: &Graph,
    ckpt4: &Checkpoint,
    data: &Dataset,
    cfg: &MethodConfig,
    g: usize,
    use_loss: bool,
) -> crate::Result<f64> {
    // Mixed config: everything at b_hi except group g at b_lo.
    let mut selected = vec![true; graph.groups.len()];
    selected[g] = false;
    let bits = BitsConfig::from_selection(graph, &selected, cfg.b_hi, cfg.b_lo);
    let ck = prepare_mp_checkpoint(ckpt4, graph, &bits, cfg.b_hi)?;
    let mut state = TrainState::new(ck);
    let tcfg = TrainConfig {
        steps: cfg.alps_steps,
        lr0: cfg.alps_lr,
        seed: 1,
        ..TrainConfig::default()
    };
    let log = finetune(rt, &mut state, data, &bits.to_f32(), &tcfg)?;
    let signal = if use_loss { log.mean_loss } else { log.mean_metric };
    crate::info!(
        "alps group {}/{} ({}) signal {:.4}",
        g + 1,
        graph.groups.len(),
        graph.groups[g].name,
        signal
    );
    Ok(signal)
}

/// Convert per-group ALPS signals to per-layer gains:
/// `G = max(A) − A_l` for accuracy tasks, `G = Loss_l` for segmentation.
fn alps_signals_to_gains(graph: &Graph, use_loss: bool, group_signal: &[f64]) -> Vec<f64> {
    let gains_per_group: Vec<f64> = if use_loss {
        group_signal.to_vec() // higher loss ⇒ more valuable at b_hi
    } else {
        let max_a = group_signal.iter().cloned().fold(f64::MIN, f64::max);
        group_signal.iter().map(|a| max_a - a).collect()
    };
    spread_group_gains(graph, &gains_per_group)
}

/// ALPS (Algorithm 1), sequential: probe each selectable group on the
/// caller's backend.
fn alps_gains<B: Backend>(
    rt: &mut B,
    graph: &Graph,
    ckpt4: &Checkpoint,
    data: &Dataset,
    cfg: &MethodConfig,
) -> crate::Result<Vec<f64>> {
    let use_loss = rt.manifest().task == Task::Seg;
    let mut group_signal = Vec::with_capacity(graph.groups.len());
    for g in 0..graph.groups.len() {
        group_signal.push(alps_probe(rt, graph, ckpt4, data, cfg, g, use_loss)?);
    }
    Ok(alps_signals_to_gains(graph, use_loss, &group_signal))
}

/// ALPS fanned out over [`job_pool`]: one group probe per job, one
/// backend per worker; bit-identical to [`alps_gains`].
pub fn alps_gains_parallel<F: BackendFactory>(
    factory: &F,
    task: Task,
    graph: &Graph,
    ckpt4: &Checkpoint,
    data: &Dataset,
    cfg: &MethodConfig,
    workers: usize,
) -> crate::Result<Vec<f64>> {
    let use_loss = task == Task::Seg;
    let items: Vec<usize> = (0..graph.groups.len()).collect();
    let group_signal = job_pool(
        items,
        workers,
        || factory.open(),
        |rt, g| alps_probe(rt, graph, ckpt4, data, cfg, g, use_loss),
    )?;
    Ok(alps_signals_to_gains(graph, use_loss, &group_signal))
}

/// One HAWQ Hutchinson draw: batch `bi`, sample `s`.  The batch is
/// regenerated from the deterministic stream, so the draw is fully
/// determined by its indices.
fn hawq_probe<B: Backend>(
    rt: &mut B,
    ckpt4: &Checkpoint,
    bits: &[f32],
    data: &Dataset,
    bi: usize,
    s: usize,
    samples: usize,
) -> crate::Result<Vec<f32>> {
    let batch = rt.manifest().train_batch;
    let (x, y) = data.batch(crate::data::Split::Train, 9_000 + bi as u64, batch);
    let seed = (bi * samples + s) as i32;
    rt.vhv_step(ckpt4, &x, &y, bits, seed)
}

/// Reduce ordered v·Hv draws into HAWQ-v3 gains:
/// `mean-Hessian-diag × ||Q4(W) − Q2(W)||²` per layer (Appendix C).
/// The f64 accumulation runs in draw order, so sequential and parallel
/// paths sum identically.
fn hawq_reduce(
    graph: &Graph,
    ckpt4: &Checkpoint,
    cfg: &MethodConfig,
    vhvs: &[Vec<f32>],
) -> crate::Result<Vec<f64>> {
    let n_layers = graph.layers.len();
    let mut trace_sum = vec![0.0f64; n_layers];
    for vhv in vhvs {
        crate::ensure!(vhv.len() == n_layers, "vhv arity");
        for (acc, &v) in trace_sum.iter_mut().zip(vhv) {
            *acc += v as f64;
        }
    }
    let n_draws = vhvs.len();
    let mut gains = vec![0.0f64; n_layers];
    for layer in &graph.layers {
        let base = layer.name.replace('.', "/");
        let w = ckpt4
            .get(&format!("{base}/w"))
            .ok_or_else(|| crate::err!("missing {base}/w"))?;
        let n = w.len() as f64;
        // Average Hessian diagonal = E[v'Hv] / n.
        let avg_diag = trace_sum[layer.qindex] / n_draws as f64 / n;
        let pert = quant::quant_error_norm2(w.f32s(), cfg.b_hi, cfg.b_lo);
        gains[layer.qindex] = avg_diag.max(0.0) * pert;
    }
    Ok(gains)
}

/// HAWQ-v3, sequential: `hawq_batches × hawq_samples` draws on the
/// caller's backend.
fn hawq_gains<B: Backend>(
    rt: &mut B,
    graph: &Graph,
    ckpt4: &Checkpoint,
    data: &Dataset,
    cfg: &MethodConfig,
) -> crate::Result<Vec<f64>> {
    let bits = BitsConfig::uniform(graph, cfg.b_hi).to_f32();
    let mut vhvs = Vec::with_capacity(cfg.hawq_batches * cfg.hawq_samples);
    for bi in 0..cfg.hawq_batches {
        for s in 0..cfg.hawq_samples {
            vhvs.push(hawq_probe(rt, ckpt4, &bits, data, bi, s, cfg.hawq_samples)?);
        }
    }
    hawq_reduce(graph, ckpt4, cfg, &vhvs)
}

/// HAWQ fanned out over [`job_pool`]: one Hutchinson draw per job, one
/// backend per worker; bit-identical to [`hawq_gains`] (draws are
/// reduced in input order).
pub fn hawq_gains_parallel<F: BackendFactory>(
    factory: &F,
    graph: &Graph,
    ckpt4: &Checkpoint,
    data: &Dataset,
    cfg: &MethodConfig,
    workers: usize,
) -> crate::Result<Vec<f64>> {
    let bits = BitsConfig::uniform(graph, cfg.b_hi).to_f32();
    let items: Vec<(usize, usize)> = (0..cfg.hawq_batches)
        .flat_map(|bi| (0..cfg.hawq_samples).map(move |s| (bi, s)))
        .collect();
    let vhvs = job_pool(
        items,
        workers,
        || factory.open(),
        |rt, (bi, s)| hawq_probe(rt, ckpt4, &bits, data, bi, s, cfg.hawq_samples),
    )?;
    hawq_reduce(graph, ckpt4, cfg, &vhvs)
}

/// Distribute per-group gains back to member layers so that group
/// re-aggregation (Σ over members) recovers exactly the group gain.
fn spread_group_gains(graph: &Graph, per_group: &[f64]) -> Vec<f64> {
    let mut per_layer = vec![0.0; graph.layers.len()];
    for (g, group) in graph.groups.iter().enumerate() {
        let share = per_group[g] / group.layer_idx.len() as f64;
        for &li in &group.layer_idx {
            per_layer[graph.layers[li].qindex] = share;
        }
    }
    per_layer
}

/// Run the selection step (§3.1) for any method at a BMAC budget.
///
/// Gain-based methods go through the 0-1 knapsack; topological baselines
/// greedily drop groups in (reverse) order until the budget is met.
pub fn select(
    kind: MethodKind,
    graph: &Graph,
    gains_per_layer: Option<&[f64]>,
    budget_bmacs: u64,
    cfg: &MethodConfig,
) -> crate::Result<(BitsConfig, Selection)> {
    let weights = graph.group_weights(cfg.b_hi, cfg.b_lo);
    let base = graph.base_bmacs(cfg.b_lo);
    let capacity = budget_bmacs.saturating_sub(base);
    let selection = match kind {
        MethodKind::FirstToLast => {
            let order: Vec<usize> = (0..graph.groups.len()).collect();
            knapsack::greedy_drop(&order, &weights, capacity)
        }
        MethodKind::LastToFirst => {
            let order: Vec<usize> = (0..graph.groups.len()).rev().collect();
            knapsack::greedy_drop(&order, &weights, capacity)
        }
        _ => {
            let gains = gains_per_layer
                .ok_or_else(|| crate::err!("{} requires gains", kind.name()))?;
            let group_gains = graph.aggregate_by_group(gains);
            knapsack::select_layers(&group_gains, &weights, capacity)
        }
    };
    let bits = BitsConfig::from_selection(graph, &selection.selected, cfg.b_hi, cfg.b_lo);
    Ok((bits, selection))
}

/// §5 extension: selection over **more than two** precision choices via
/// the multiple-choice knapsack (paper: "both methods can be used with
/// more than two precision choices by changing the optimizer").
///
/// Each selectable group becomes an MCKP class with one option per entry
/// of `choices` (ascending bit-widths, e.g. [2, 4, 8]); option value
/// interpolates the group's gain on the bit axis (exactly reproducing the
/// binary case for two choices) and option weight is the group's BMACs at
/// that precision.  Returns the per-layer `BitsConfig`.
pub fn select_multi(
    graph: &Graph,
    gains_per_layer: &[f64],
    choices: &[u32],
    budget_bmacs: u64,
) -> crate::Result<BitsConfig> {
    crate::ensure!(choices.len() >= 2, "need at least two precision choices");
    let b_min = *choices.first().unwrap();
    let b_max = *choices.last().unwrap();
    let group_gains = graph.aggregate_by_group(gains_per_layer);
    let gq = knapsack::quantize_gains(&group_gains);
    let classes: Vec<Vec<knapsack::mckp::Choice>> = graph
        .groups
        .iter()
        .enumerate()
        .map(|(g, group)| {
            choices
                .iter()
                .map(|&b| knapsack::mckp::Choice {
                    value: knapsack::mckp::gain_at(gq[g], b, b_min, b_max),
                    weight: group.macs * b as u64,
                })
                .collect()
        })
        .collect();
    let sel = knapsack::mckp::solve_mckp(&classes, budget_bmacs)
        .ok_or_else(|| crate::err!("budget below the all-{b_min}-bit cost"))?;
    let mut bits = BitsConfig::uniform(graph, b_max);
    for (g, group) in graph.groups.iter().enumerate() {
        let b = choices[sel.choice_per_class[g]];
        for &li in &group.layer_idx {
            if graph.layers[li].fixed_bits.is_none() {
                bits.bits[graph.layers[li].qindex] = b;
            }
        }
    }
    Ok(bits)
}

/// Build the mixed-precision starting checkpoint: clone the `b_hi`
/// checkpoint and rescale learned step sizes (×2^(b_hi−b)) for every layer
/// dropped below `b_hi` (paper §3.4.3: "initial quantization step-size is
/// set to 4s").
pub fn prepare_mp_checkpoint(
    ckpt4: &Checkpoint,
    graph: &Graph,
    bits: &BitsConfig,
    b_hi: u32,
) -> crate::Result<Checkpoint> {
    let mut ck = ckpt4.clone();
    for layer in &graph.layers {
        let b = bits.bits[layer.qindex];
        if layer.fixed_bits.is_none() && b < b_hi {
            quant::rescale_steps_for_drop(&mut ck, &layer.name, b_hi, b)?;
        }
    }
    Ok(ck)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;

    fn toy_graph() -> Graph {
        let m = jsonio::parse(
            r#"{
          "model": "toy",
          "layers": [
            {"name":"stem","kind":"conv","qindex":0,"link_group":"stem",
             "macs":100,"weight_params":10,"fixed_bits":8},
            {"name":"a","kind":"conv","qindex":1,"link_group":"a",
             "macs":1000,"weight_params":100,"fixed_bits":null},
            {"name":"b","kind":"conv","qindex":2,"link_group":"b",
             "macs":1000,"weight_params":100,"fixed_bits":null},
            {"name":"c","kind":"conv","qindex":3,"link_group":"c",
             "macs":1000,"weight_params":100,"fixed_bits":null}
          ]
        }"#,
        )
        .unwrap();
        Graph::from_manifest(&m).unwrap()
    }

    #[test]
    fn knapsack_select_prefers_high_gain() {
        let g = toy_graph();
        let cfg = MethodConfig::default();
        // Budget allows exactly 2 of 3 groups at 4-bit:
        // base (all 2-bit) = 6000, budget 10000 → capacity 4000, each
        // group's extra = 2000.
        let gains = vec![0.0, 0.1, 0.9, 0.5];
        let (bits, sel) = select(MethodKind::Eagl, &g, Some(&gains), 10_000, &cfg).unwrap();
        assert_eq!(sel.selected, vec![false, true, true]);
        assert_eq!(bits.bits, vec![8, 2, 4, 4]);
    }

    #[test]
    fn first_to_last_drops_front() {
        let g = toy_graph();
        let cfg = MethodConfig::default();
        let (bits, _) = select(MethodKind::FirstToLast, &g, None, 10_000, &cfg).unwrap();
        assert_eq!(bits.bits, vec![8, 2, 4, 4]);
        let (bits, _) = select(MethodKind::LastToFirst, &g, None, 10_000, &cfg).unwrap();
        assert_eq!(bits.bits, vec![8, 4, 4, 2]);
    }

    #[test]
    fn full_budget_keeps_everything() {
        let g = toy_graph();
        let cfg = MethodConfig::default();
        let gains = vec![0.0, 0.3, 0.2, 0.1];
        let (bits, _) = select(MethodKind::Eagl, &g, Some(&gains), 12_000, &cfg).unwrap();
        assert_eq!(bits.bits, vec![8, 4, 4, 4]);
    }

    #[test]
    fn min_budget_drops_everything() {
        let g = toy_graph();
        let cfg = MethodConfig::default();
        let gains = vec![0.0, 0.3, 0.2, 0.1];
        let (bits, _) = select(MethodKind::Eagl, &g, Some(&gains), 6_000, &cfg).unwrap();
        assert_eq!(bits.bits, vec![8, 2, 2, 2]);
    }

    #[test]
    fn spread_gains_reaggregates_exactly() {
        let g = toy_graph();
        let per_group = vec![0.5, 1.5, 2.5];
        let per_layer = spread_group_gains(&g, &per_group);
        let back = g.aggregate_by_group(&per_layer);
        for (a, b) in back.iter().zip(&per_group) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn select_multi_reduces_to_binary_and_respects_budget() {
        let g = toy_graph();
        let gains = vec![0.0, 0.1, 0.9, 0.5];
        // Two-choice MCKP == the 0-1 path: budget 10000 keeps the two
        // highest-gain groups at 4-bit (matches knapsack_select test).
        let bits = select_multi(&g, &gains, &[2, 4], 10_000).unwrap();
        assert_eq!(bits.bits, vec![8, 2, 4, 4]);
        // Three choices: a looser budget lets the top group go to 8-bit.
        let bits = select_multi(&g, &gains, &[2, 4, 8], 14_000).unwrap();
        let cost: u64 = g
            .groups
            .iter()
            .map(|gr| gr.macs * bits.bits[g.layers[gr.layer_idx[0]].qindex] as u64)
            .sum();
        assert!(cost <= 14_000);
        // Highest-gain group gets the most bits.
        assert!(bits.bits[2] >= bits.bits[1]);
        // Infeasible budget errors.
        assert!(select_multi(&g, &gains, &[2, 4], 1_000).is_err());
    }

    #[test]
    fn method_parse_round_trip() {
        for kind in [
            MethodKind::Eagl,
            MethodKind::Alps,
            MethodKind::HawqV3,
            MethodKind::Uniform,
            MethodKind::FirstToLast,
            MethodKind::LastToFirst,
            MethodKind::Oracle,
        ] {
            assert_eq!(MethodKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(MethodKind::parse("bogus").is_err());
    }
}
