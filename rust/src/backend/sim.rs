//! SimBackend — the hermetic pure-Rust reference executor.
//!
//! Synthesizes small proxy classification models (linear "link groups"
//! with seeded-RNG weights) and implements the four pipeline entry points
//! (`train_step`, `eval_step`, `vhv_step`, `eagl_step`) directly on host
//! tensors, honoring per-layer [`crate::quant::BitsConfig`] quantization:
//! LSQ fake-quantized weights (signed) and activations (unsigned,
//! post-ReLU) with clipped straight-through gradients, SGD-momentum
//! updates, and a finite-difference Hutchinson v·Hv for HAWQ.  Everything
//! is deterministic: same inputs → bit-identical outputs, so the full
//! EAGL/ALPS pipeline runs and is testable with no AOT artifacts.
//!
//! ## Proxy models
//!
//! The input is the textures classification task
//! ([`crate::data::Dataset::for_task`] with [`crate::backend::Task::Cls`]); a fixed,
//! parameter-free Gabor-energy featurizer reduces each 32×32×3 image to
//! 10 oriented-grating energies (one per class generator), after which a
//! stack of quantized linear layers discriminates.  Two models ship:
//!
//! * `sim_tiny` — 4 layers, for fast pipeline tests;
//! * `sim_skew` — 6 layers engineered so EAGL's premise *holds by
//!   construction*: a high-entropy `wide` layer carries the main path
//!   (dropping it to 2-bit is destructive), while low-entropy layers
//!   (`idty`, `mix_a`, `mix_b`) are small-gain residual branches whose
//!   2-bit quantization is nearly harmless.  Layer `macs` are skewed so
//!   a mid-range budget forces the knapsack to choose between them.

use std::collections::HashMap;

use crate::ckpt::Checkpoint;
use crate::eagl;
use crate::jsonio::Json;
use crate::quant;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

use super::manifest::Manifest;
use super::Backend;

/// Residual branch gain: out = in + GAMMA * branch(in).
const GAMMA: f32 = 0.05;
/// SGD momentum of the fused train step.
const MOMENTUM: f32 = 0.9;
/// Featurizer output scale (puts class energies at O(1)).
const FEAT_SCALE: f32 = 6.0;
/// Finite-difference step of the Hutchinson v·Hv probe.
const VHV_EPS: f32 = 1e-2;
/// Precision the `eagl_step` entry scores selectable layers at.  Like the
/// AOT artifact (whose entropy graph is lowered at the default `b_hi`),
/// the entry is fixed at 4-bit; fixed layers score at their pinned bits.
/// Callers needing another precision use the native
/// [`crate::eagl::checkpoint_entropies`] directly.
const EAGL_CKPT_BITS: u32 = 4;
/// Image side and feature count of the textures task.
const IMG: usize = 32;
const N_FEATURES: usize = 10;
const N_CLASSES: usize = 10;

/// Static spec of one sim layer.
#[derive(Debug, Clone)]
struct SimLayer {
    name: &'static str,
    fan_in: usize,
    fan_out: usize,
    link_group: &'static str,
    fixed_bits: Option<u32>,
    /// Residual side branch (out = in + GAMMA*layer(in)); needs fan_in == fan_out.
    branch: bool,
    w_sigma: f32,
    sw: f32,
    sa: f32,
    macs: u64,
}

#[allow(clippy::too_many_arguments)]
fn lay(
    name: &'static str,
    fan_in: usize,
    fan_out: usize,
    link_group: &'static str,
    fixed_bits: Option<u32>,
    branch: bool,
    w_sigma: f32,
    sw: f32,
    sa: f32,
    macs: u64,
) -> SimLayer {
    SimLayer {
        name,
        fan_in,
        fan_out,
        link_group,
        fixed_bits,
        branch,
        w_sigma,
        sw,
        sa,
        macs,
    }
}

fn layers_for(model: &str) -> Option<Vec<SimLayer>> {
    match model {
        "sim_tiny" => Some(vec![
            lay("stem", N_FEATURES, 12, "stem", Some(8), false, 0.45, 0.19, 0.10, 120),
            lay("h1", 12, 12, "h1", None, false, 0.30, 0.15, 0.10, 500),
            lay("h2", 12, 12, "h2", None, true, 0.10, 0.20, 0.10, 500),
            lay("head", 12, N_CLASSES, "head", Some(8), false, 0.35, 0.12, 0.10, 120),
        ]),
        "sim_skew" => Some(vec![
            lay("stem", N_FEATURES, 16, "stem", Some(8), false, 0.45, 0.19, 0.10, 160),
            lay("wide", 16, 16, "wide", None, false, 0.35, 0.12, 0.30, 6000),
            lay("idty", 16, 16, "idty", None, true, 0.02, 0.25, 0.10, 400),
            lay("mix_a", 16, 16, "mix", None, true, 0.10, 0.20, 0.10, 400),
            lay("mix_b", 16, 16, "mix", None, true, 0.10, 0.20, 0.10, 400),
            lay("head", 16, N_CLASSES, "head", Some(8), false, 0.35, 0.12, 0.10, 160),
        ]),
        _ => None,
    }
}

/// Names of the available sim models (for error messages / docs).
pub const SIM_MODELS: &[&str] = &["sim_tiny", "sim_skew"];

/// Owned, per-call view of one layer's parameters.
#[derive(Clone)]
struct NetLayer {
    w: Vec<f32>,
    b: Vec<f32>,
    sw: f32,
    sa: f32,
}

/// Per-layer forward cache for the backward pass.
struct LayerCache {
    /// Input activations [batch * fan_in].
    a_in: Vec<f32>,
    /// Pre-activations [batch * fan_out].
    z: Vec<f32>,
    /// Fake-quantized weights [fan_in * fan_out].
    wq: Vec<f32>,
    /// Weight code inside clamp range (clipped STE mask).
    w_in: Vec<bool>,
    /// Activation below the unsigned clamp (clipped STE mask); empty for
    /// the head layer (logits are not quantized).
    act_in: Vec<bool>,
}

/// The hermetic reference backend.
pub struct SimBackend {
    manifest: Manifest,
    layers: Vec<SimLayer>,
    /// Gabor featurizer basis, [N_FEATURES][IMG*IMG], flattened.
    basis_cos: Vec<f32>,
    basis_sin: Vec<f32>,
    /// Cumulative executions per entry (perf accounting parity with pjrt).
    pub exec_counts: HashMap<String, u64>,
}

impl SimBackend {
    /// Build the sim backend for one of the [`SIM_MODELS`].
    pub fn new(model: &str) -> crate::Result<SimBackend> {
        let layers = layers_for(model).ok_or_else(|| {
            crate::err!(
                "unknown sim model '{model}' (available: {}); artifact models \
                 need the pjrt backend",
                SIM_MODELS.join(", ")
            )
        })?;
        // Chain consistency (defensive — specs are static).
        for win in layers.windows(2) {
            let carried = if win[1].branch { win[1].fan_out } else { win[1].fan_in };
            crate::ensure!(
                win[0].fan_out == win[1].fan_in && win[1].fan_in == carried,
                "sim model '{model}': fan mismatch {} -> {}",
                win[0].name,
                win[1].name
            );
        }
        let manifest = Manifest::from_json(manifest_json(model, &layers))?;
        let (basis_cos, basis_sin) = featurizer_basis();
        Ok(SimBackend {
            manifest,
            layers,
            basis_cos,
            basis_sin,
            exec_counts: HashMap::new(),
        })
    }

    /// Canonical parameter names, 4 per layer: w, b, sw, sa.
    fn param_names(&self) -> Vec<String> {
        let mut names = Vec::with_capacity(4 * self.layers.len());
        for l in &self.layers {
            for suffix in ["w", "b", "sw", "sa"] {
                names.push(format!("{}/{}", l.name, suffix));
            }
        }
        names
    }

    // -- entry implementations ----------------------------------------------

    fn net_from_params(&self, params: &[&Tensor]) -> crate::Result<Vec<NetLayer>> {
        crate::ensure!(
            params.len() == 4 * self.layers.len(),
            "sim: expected {} param tensors, got {}",
            4 * self.layers.len(),
            params.len()
        );
        let mut net = Vec::with_capacity(self.layers.len());
        for (li, l) in self.layers.iter().enumerate() {
            let w = params[4 * li];
            let b = params[4 * li + 1];
            crate::ensure!(
                w.len() == l.fan_in * l.fan_out && b.len() == l.fan_out,
                "sim: bad param shape for layer {}",
                l.name
            );
            net.push(NetLayer {
                w: w.f32s().to_vec(),
                b: b.f32s().to_vec(),
                sw: params[4 * li + 2].item(),
                sa: params[4 * li + 3].item(),
            });
        }
        Ok(net)
    }

    fn layer_bits(&self, li: usize, bits: &[f32]) -> u32 {
        self.layers[li]
            .fixed_bits
            .unwrap_or_else(|| bits[li].round().max(1.0) as u32)
    }

    /// Gabor-energy featurizer: [batch * N_FEATURES], O(1) class energies.
    fn featurize(&self, x: &Tensor) -> crate::Result<(Vec<f32>, usize)> {
        crate::ensure!(
            x.shape.len() == 4 && x.shape[1] == IMG && x.shape[2] == IMG && x.shape[3] == 3,
            "sim: expected x of shape [B,{IMG},{IMG},3], got {:?}",
            x.shape
        );
        let batch = x.shape[0];
        let xs = x.f32s();
        let px = IMG * IMG;
        let mut feats = vec![0f32; batch * N_FEATURES];
        let mut gray = vec![0f32; px];
        for b in 0..batch {
            for (i, g) in gray.iter_mut().enumerate() {
                let o = (b * px + i) * 3;
                *g = (xs[o] + xs[o + 1] + xs[o + 2]) / 3.0 - 0.5;
            }
            for k in 0..N_FEATURES {
                let (mut c, mut s) = (0f64, 0f64);
                let cb = &self.basis_cos[k * px..(k + 1) * px];
                let sb = &self.basis_sin[k * px..(k + 1) * px];
                for i in 0..px {
                    c += (gray[i] * cb[i]) as f64;
                    s += (gray[i] * sb[i]) as f64;
                }
                feats[b * N_FEATURES + k] =
                    ((c * c + s * s).sqrt() as f32) * (2.0 / px as f32) * FEAT_SCALE;
            }
        }
        Ok((feats, batch))
    }

    /// Quantized forward pass; returns (logits, per-layer caches).
    fn forward(
        &self,
        net: &[NetLayer],
        bits: &[f32],
        feats: &[f32],
        batch: usize,
    ) -> (Vec<f32>, Vec<LayerCache>) {
        let n_layers = self.layers.len();
        let mut a = feats.to_vec();
        let mut caches = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            let spec = &self.layers[li];
            let p = &net[li];
            let (fi, fo) = (spec.fan_in, spec.fan_out);
            let b_eff = self.layer_bits(li, bits);
            let (qn, qp) = quant::qrange_signed(b_eff);
            let mut wq = vec![0f32; fi * fo];
            let mut w_in = vec![false; fi * fo];
            for (i, &w) in p.w.iter().enumerate() {
                let code = (w / p.sw).round();
                w_in[i] = code >= qn && code <= qp;
                wq[i] = code.clamp(qn, qp) * p.sw;
            }
            // z = a @ wq + b
            let mut z = vec![0f32; batch * fo];
            for bi in 0..batch {
                let arow = &a[bi * fi..(bi + 1) * fi];
                let zrow = &mut z[bi * fo..(bi + 1) * fo];
                zrow.copy_from_slice(&p.b);
                for (i, &av) in arow.iter().enumerate() {
                    if av != 0.0 {
                        let wrow = &wq[i * fo..(i + 1) * fo];
                        for (o, zv) in zrow.iter_mut().enumerate() {
                            *zv += av * wrow[o];
                        }
                    }
                }
            }
            let last = li == n_layers - 1;
            if last {
                caches.push(LayerCache {
                    a_in: std::mem::take(&mut a),
                    z: z.clone(),
                    wq,
                    w_in,
                    act_in: Vec::new(),
                });
                a = z;
            } else {
                // relu → unsigned fake-quant with clipped STE mask.
                let (_, aqp) = quant::qrange_unsigned(b_eff);
                let mut hq = vec![0f32; batch * fo];
                let mut act_in = vec![false; batch * fo];
                for (i, &zv) in z.iter().enumerate() {
                    let h = zv.max(0.0);
                    let code = (h / p.sa).round();
                    act_in[i] = h / p.sa <= aqp;
                    hq[i] = code.clamp(0.0, aqp) * p.sa;
                }
                let a_in = std::mem::take(&mut a);
                a = if spec.branch {
                    let mut out = a_in.clone();
                    for (o, &hv) in out.iter_mut().zip(&hq) {
                        *o += GAMMA * hv;
                    }
                    out
                } else {
                    hq
                };
                caches.push(LayerCache { a_in, z, wq, w_in, act_in });
            }
        }
        (a, caches)
    }

    /// Softmax cross-entropy: (mean loss, dlogits/batch, correct count).
    fn softmax_ce(logits: &[f32], y: &[i32], batch: usize) -> (f32, Vec<f32>, usize) {
        let c = N_CLASSES;
        let mut dlogits = vec![0f32; batch * c];
        let mut loss = 0f64;
        let mut correct = 0usize;
        for b in 0..batch {
            let row = &logits[b * c..(b + 1) * c];
            let mut mx = f32::NEG_INFINITY;
            let mut argmax = 0usize;
            for (k, &v) in row.iter().enumerate() {
                if v > mx {
                    mx = v;
                    argmax = k;
                }
            }
            let mut denom = 0f64;
            for &v in row {
                denom += ((v - mx) as f64).exp();
            }
            let yi = y[b] as usize;
            let p_y = ((row[yi] - mx) as f64).exp() / denom;
            loss -= (p_y + 1e-12).ln();
            if argmax == yi {
                correct += 1;
            }
            for k in 0..c {
                let p = ((row[k] - mx) as f64).exp() / denom;
                dlogits[b * c + k] =
                    ((p - if k == yi { 1.0 } else { 0.0 }) / batch as f64) as f32;
            }
        }
        ((loss / batch as f64) as f32, dlogits, correct)
    }

    /// Full forward + backward: per-layer (dW, db) with clipped STE, plus
    /// (loss, correct count).
    fn grads(
        &self,
        net: &[NetLayer],
        bits: &[f32],
        feats: &[f32],
        y: &[i32],
        batch: usize,
    ) -> (Vec<(Vec<f32>, Vec<f32>)>, f32, usize) {
        let n_layers = self.layers.len();
        let (logits, caches) = self.forward(net, bits, feats, batch);
        let (loss, dlogits, correct) = Self::softmax_ce(&logits, y, batch);
        let mut grads: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(n_layers);
        grads.resize_with(n_layers, || (Vec::new(), Vec::new()));
        let mut d = dlogits;
        for li in (0..n_layers).rev() {
            let spec = &self.layers[li];
            let cache = &caches[li];
            let (fi, fo) = (spec.fan_in, spec.fan_out);
            let last = li == n_layers - 1;
            // Gradient at the layer's pre-activation output.
            let dbr: Vec<f32> = if last {
                d.clone()
            } else {
                let scale = if spec.branch { GAMMA } else { 1.0 };
                d.iter()
                    .enumerate()
                    .map(|(i, &dv)| {
                        if cache.act_in[i] && cache.z[i] > 0.0 {
                            dv * scale
                        } else {
                            0.0
                        }
                    })
                    .collect()
            };
            // dW = a_inᵀ · dbr (masked), db = Σ_b dbr.
            let mut dw = vec![0f32; fi * fo];
            let mut db = vec![0f32; fo];
            for bi in 0..batch {
                let arow = &cache.a_in[bi * fi..(bi + 1) * fi];
                let drow = &dbr[bi * fo..(bi + 1) * fo];
                for (o, &dv) in drow.iter().enumerate() {
                    db[o] += dv;
                }
                for (i, &av) in arow.iter().enumerate() {
                    if av != 0.0 {
                        let wrow = &mut dw[i * fo..(i + 1) * fo];
                        for (o, &dv) in drow.iter().enumerate() {
                            wrow[o] += av * dv;
                        }
                    }
                }
            }
            for (i, g) in dw.iter_mut().enumerate() {
                if !cache.w_in[i] {
                    *g = 0.0;
                }
            }
            // d_in = dbr · wqᵀ.
            let mut d_in = vec![0f32; batch * fi];
            for bi in 0..batch {
                let drow = &dbr[bi * fo..(bi + 1) * fo];
                let irow = &mut d_in[bi * fi..(bi + 1) * fi];
                for (i, iv) in irow.iter_mut().enumerate() {
                    let wrow = &cache.wq[i * fo..(i + 1) * fo];
                    let mut acc = 0f32;
                    for (o, &dv) in drow.iter().enumerate() {
                        acc += dv * wrow[o];
                    }
                    *iv = acc;
                }
            }
            d = if !last && spec.branch {
                // Skip connection: upstream gradient passes through.
                d.iter().zip(&d_in).map(|(&a, &b)| a + b).collect()
            } else {
                d_in
            };
            grads[li] = (dw, db);
        }
        (grads, loss, correct)
    }

    fn exec_train(&self, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let n = 4 * self.layers.len();
        crate::ensure!(args.len() == 2 * n + 5, "sim train_step: arity {}", args.len());
        let net = self.net_from_params(&args[..n])?;
        let mom_args = &args[n..2 * n];
        let x = args[2 * n];
        let y_t = args[2 * n + 1];
        let lr = args[2 * n + 2].item();
        let wd = args[2 * n + 3].item();
        let bits = args[2 * n + 4].f32s();
        crate::ensure!(bits.len() == self.layers.len(), "sim: bits arity");
        let (feats, batch) = self.featurize(x)?;
        let y = y_t.i32s();
        crate::ensure!(y.len() == batch, "sim: y arity");
        let (grads, loss, correct) = self.grads(&net, bits, &feats, y, batch);
        // SGD momentum update (wd on weights only; step sizes are inert).
        let mut out = Vec::with_capacity(2 * n + 2);
        let mut mom_out = Vec::with_capacity(n);
        for (li, l) in self.layers.iter().enumerate() {
            let p = &net[li];
            let (dw, db) = &grads[li];
            let mw_old = mom_args[4 * li].f32s();
            let mb_old = mom_args[4 * li + 1].f32s();
            let mut w_new = p.w.clone();
            let mut mw_new = vec![0f32; p.w.len()];
            for i in 0..p.w.len() {
                mw_new[i] = MOMENTUM * mw_old[i] + dw[i] + wd * p.w[i];
                w_new[i] -= lr * mw_new[i];
            }
            let mut b_new = p.b.clone();
            let mut mb_new = vec![0f32; p.b.len()];
            for o in 0..p.b.len() {
                mb_new[o] = MOMENTUM * mb_old[o] + db[o];
                b_new[o] -= lr * mb_new[o];
            }
            out.push(Tensor::from_f32(&[l.fan_in, l.fan_out], w_new));
            out.push(Tensor::from_f32(&[l.fan_out], b_new));
            out.push((*args[4 * li + 2]).clone()); // sw (inert)
            out.push((*args[4 * li + 3]).clone()); // sa (inert)
            mom_out.push(Tensor::from_f32(&[l.fan_in, l.fan_out], mw_new));
            mom_out.push(Tensor::from_f32(&[l.fan_out], mb_new));
            mom_out.push((*mom_args[4 * li + 2]).clone());
            mom_out.push((*mom_args[4 * li + 3]).clone());
        }
        out.extend(mom_out);
        out.push(Tensor::scalar(loss));
        out.push(Tensor::scalar(correct as f32 / batch as f32));
        Ok(out)
    }

    fn exec_eval(&self, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let n = 4 * self.layers.len();
        crate::ensure!(args.len() == n + 3, "sim eval_step: arity {}", args.len());
        let net = self.net_from_params(&args[..n])?;
        let x = args[n];
        let y_t = args[n + 1];
        let bits = args[n + 2].f32s();
        crate::ensure!(bits.len() == self.layers.len(), "sim: bits arity");
        let (feats, batch) = self.featurize(x)?;
        let y = y_t.i32s();
        crate::ensure!(y.len() == batch, "sim: y arity");
        let (logits, _) = self.forward(&net, bits, &feats, batch);
        let (loss, _, correct) = Self::softmax_ce(&logits, y, batch);
        Ok(vec![
            Tensor::scalar(loss),
            Tensor::from_f32(&[], vec![correct as f32]),
        ])
    }

    fn exec_vhv(&self, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let n = 4 * self.layers.len();
        crate::ensure!(args.len() == n + 4, "sim vhv_step: arity {}", args.len());
        let net = self.net_from_params(&args[..n])?;
        let x = args[n];
        let y_t = args[n + 1];
        let bits = args[n + 2].f32s();
        let seed = args[n + 3].i32s()[0];
        let (feats, batch) = self.featurize(x)?;
        let y = y_t.i32s();
        crate::ensure!(y.len() == batch, "sim: y arity");
        // Rademacher probe per layer, deterministic in the seed.
        let mut rng = Pcg32::new(seed as u32 as u64, 0x6876_7673);
        let vs: Vec<Vec<f32>> = self
            .layers
            .iter()
            .map(|l| (0..l.fan_in * l.fan_out).map(|_| rng.rademacher()).collect())
            .collect();
        let (g0, _, _) = self.grads(&net, bits, &feats, y, batch);
        let mut net2 = net.clone();
        for (li, v) in vs.iter().enumerate() {
            for (w, &vv) in net2[li].w.iter_mut().zip(v) {
                *w += VHV_EPS * vv;
            }
        }
        let (g1, _, _) = self.grads(&net2, bits, &feats, y, batch);
        let mut vhv = vec![0f32; self.layers.len()];
        for li in 0..self.layers.len() {
            let mut acc = 0f64;
            for (i, &vv) in vs[li].iter().enumerate() {
                acc += ((g1[li].0[i] - g0[li].0[i]) / VHV_EPS * vv) as f64;
            }
            vhv[li] = acc as f32;
        }
        Ok(vec![Tensor::from_f32(&[self.layers.len()], vhv)])
    }

    fn exec_eagl(&self, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let n_layers = self.layers.len();
        crate::ensure!(args.len() == 2 * n_layers, "sim eagl_step: arity {}", args.len());
        let mut out = vec![0f32; n_layers];
        for (li, l) in self.layers.iter().enumerate() {
            let w = args[2 * li];
            let sw = args[2 * li + 1].item();
            let b_eff = l.fixed_bits.unwrap_or(EAGL_CKPT_BITS);
            out[li] = eagl::layer_entropy(w.f32s(), sw, b_eff) as f32;
        }
        Ok(vec![Tensor::from_f32(&[n_layers], out)])
    }
}

impl Backend for SimBackend {
    fn kind(&self) -> &'static str {
        "sim"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Deterministic seeded-RNG initial checkpoint: per-layer Gaussian
    /// weights (stream keyed by layer index), zero biases, configured
    /// step sizes.
    fn init_checkpoint(&self) -> crate::Result<Checkpoint> {
        let mut tensors = Vec::with_capacity(4 * self.layers.len());
        for (li, l) in self.layers.iter().enumerate() {
            let mut rng = Pcg32::new(
                0x51AB_0000_0000_0000 ^ (li as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                0x1417,
            );
            let w: Vec<f32> = (0..l.fan_in * l.fan_out)
                .map(|_| l.w_sigma * rng.normal())
                .collect();
            tensors.push(Tensor::from_f32(&[l.fan_in, l.fan_out], w));
            tensors.push(Tensor::zeros(&[l.fan_out]));
            tensors.push(Tensor::from_f32(&[], vec![l.sw]));
            tensors.push(Tensor::from_f32(&[], vec![l.sa]));
        }
        Ok(Checkpoint::new(self.param_names(), tensors))
    }

    fn execute(&mut self, entry: &str, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        *self.exec_counts.entry(entry.to_string()).or_insert(0) += 1;
        match entry {
            "train_step" => self.exec_train(args),
            "eval_step" => self.exec_eval(args),
            "vhv_step" => self.exec_vhv(args),
            "eagl_step" => self.exec_eagl(args),
            other => crate::bail!("sim backend: unknown entry '{other}'"),
        }
    }
}

/// Fixed oriented-grating (Gabor) correlation basis matching the textures
/// generator in [`crate::data`]: one (orientation, frequency) pair per
/// class.
fn featurizer_basis() -> (Vec<f32>, Vec<f32>) {
    let px = IMG * IMG;
    let mut cos_b = vec![0f32; N_FEATURES * px];
    let mut sin_b = vec![0f32; N_FEATURES * px];
    for k in 0..N_FEATURES {
        let (theta, freq) = crate::data::texture_class_params(k);
        let (st, ct) = theta.sin_cos();
        for i in 0..IMG {
            for j in 0..IMG {
                let u = (i as f32 - IMG as f32 / 2.0) / IMG as f32;
                let v = (j as f32 - IMG as f32 / 2.0) / IMG as f32;
                let t = (u * ct + v * st) * freq * std::f32::consts::TAU;
                cos_b[k * px + i * IMG + j] = t.cos();
                sin_b[k * px + i * IMG + j] = t.sin();
            }
        }
    }
    (cos_b, sin_b)
}

/// Synthesize the manifest JSON for a sim model (same schema as the AOT
/// path's `<model>.manifest.json`).
fn manifest_json(model: &str, layers: &[SimLayer]) -> Json {
    let mut params = Vec::new();
    for l in layers {
        params.push(param_spec(l.name, "w", vec![l.fan_in, l.fan_out]));
        params.push(param_spec(l.name, "b", vec![l.fan_out]));
        params.push(param_spec(l.name, "sw", vec![]));
        params.push(param_spec(l.name, "sa", vec![]));
    }
    let layer_rows: Vec<Json> = layers
        .iter()
        .enumerate()
        .map(|(qindex, l)| {
            Json::obj(vec![
                ("name", Json::str(l.name)),
                ("kind", Json::str("linear")),
                ("qindex", Json::num(qindex as f64)),
                ("link_group", Json::str(l.link_group)),
                ("macs", Json::num(l.macs as f64)),
                ("weight_params", Json::num((l.fan_in * l.fan_out) as f64)),
                (
                    "fixed_bits",
                    match l.fixed_bits {
                        Some(b) => Json::num(b as f64),
                        None => Json::Null,
                    },
                ),
            ])
        })
        .collect();
    let entry = |order: &[&str], outputs: &[&str]| {
        Json::obj(vec![
            ("file", Json::str("<sim builtin>")),
            ("order", Json::arr(order.iter().map(|s| Json::str(s)))),
            ("outputs", Json::arr(outputs.iter().map(|s| Json::str(s)))),
        ])
    };
    let entries = Json::obj(vec![
        (
            "train_step",
            entry(
                &["params", "mom", "x", "y", "lr", "wd", "bits"],
                &["params", "mom", "loss", "metric"],
            ),
        ),
        ("eval_step", entry(&["params", "x", "y", "bits"], &["loss", "evalout"])),
        ("vhv_step", entry(&["params", "x", "y", "bits", "seed"], &["vhv"])),
        ("eagl_step", entry(&["w_sw"], &["entropies"])),
    ]);
    let usizes = |v: &[usize]| Json::arr(v.iter().map(|&d| Json::num(d as f64)));
    let meta = Json::obj(vec![
        ("n_bits", Json::num(layers.len() as f64)),
        ("train_batch", Json::num(16.0)),
        ("eval_batch", Json::num(64.0)),
        ("task", Json::str("cls")),
        ("x_train_shape", usizes(&[16, IMG, IMG, 3])),
        ("y_train_shape", usizes(&[16])),
        ("x_eval_shape", usizes(&[64, IMG, IMG, 3])),
        ("y_eval_shape", usizes(&[64])),
        ("x_dtype", Json::str("float32")),
        ("y_dtype", Json::str("int32")),
        ("evalout_shape", usizes(&[])),
    ]);
    Json::obj(vec![
        ("model", Json::str(model)),
        ("params", Json::Arr(params)),
        ("layers", Json::Arr(layer_rows)),
        ("entries", entries),
        ("meta", meta),
    ])
}

fn param_spec(layer: &str, suffix: &str, shape: Vec<usize>) -> Json {
    Json::obj(vec![
        ("name", Json::str(&format!("{layer}/{suffix}"))),
        ("shape", Json::arr(shape.iter().map(|&d| Json::num(d as f64)))),
        ("dtype", Json::str("float32")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, Split};
    use crate::graph::Graph;
    use crate::quant::BitsConfig;

    #[test]
    fn unknown_model_is_actionable() {
        let err = SimBackend::new("qresnet20").unwrap_err().to_string();
        assert!(err.contains("sim_tiny"), "{err}");
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn manifest_graph_and_checkpoint_are_consistent() {
        for model in SIM_MODELS {
            let be = SimBackend::new(model).unwrap();
            let m = be.manifest();
            assert_eq!(m.model, *model);
            let graph = Graph::from_manifest(&m.raw).unwrap();
            assert_eq!(graph.n_bits(), m.n_bits);
            assert!(!graph.groups.is_empty());
            let ck = be.init_checkpoint().unwrap();
            assert_eq!(ck.names.len(), m.params.len());
            for (name, spec) in ck.names.iter().zip(&m.params) {
                assert_eq!(name, &spec.name);
            }
        }
    }

    #[test]
    fn init_checkpoint_is_deterministic() {
        let be = SimBackend::new("sim_tiny").unwrap();
        let a = be.init_checkpoint().unwrap();
        let b = be.init_checkpoint().unwrap();
        for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
            assert_eq!(ta, tb);
        }
    }

    #[test]
    fn eval_runs_and_counts_correct() {
        let mut be = SimBackend::new("sim_tiny").unwrap();
        let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
        let data = Dataset::for_task(be.manifest().task, 1);
        let ck = be.init_checkpoint().unwrap();
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let batch = be.manifest().eval_batch;
        let (x, y) = data.batch(Split::Eval, 0, batch);
        let (loss, out) = be.eval_step(&ck, &x, &y, &bits).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(out.shape, be.manifest().evalout_shape);
        let correct = out.item();
        assert!((0.0..=batch as f32).contains(&correct), "correct={correct}");
        assert_eq!(be.exec_counts.get("eval_step"), Some(&1));
    }

    #[test]
    fn skew_init_entropies_are_ordered() {
        // The engineered premise: wide ≫ mix layers ≫ idty at init.
        let mut be = SimBackend::new("sim_skew").unwrap();
        let ck = be.init_checkpoint().unwrap();
        let ents = be.eagl_step(&ck).unwrap();
        let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
        let h = |name: &str| {
            let l = graph.layers.iter().find(|l| l.name == name).unwrap();
            ents[l.qindex] as f64
        };
        assert!(h("wide") > 3.0, "wide H = {}", h("wide"));
        assert!(h("idty") < 0.5, "idty H = {}", h("idty"));
        assert!(h("mix_a") + h("mix_b") < h("wide"), "mix group must stay below wide");
    }
}
