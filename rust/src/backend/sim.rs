//! SimBackend — the hermetic pure-Rust reference executor.
//!
//! Synthesizes small proxy classification models (linear "link groups"
//! with seeded-RNG weights) and implements the four pipeline entry points
//! (`train_step`, `eval_step`, `vhv_step`, `eagl_step`) directly on host
//! tensors, honoring per-layer [`crate::quant::BitsConfig`] quantization:
//! LSQ fake-quantized weights (signed) and activations (unsigned,
//! post-ReLU) with clipped straight-through gradients, SGD-momentum
//! updates, and a finite-difference Hutchinson v·Hv for HAWQ.  Everything
//! is deterministic: same inputs → bit-identical outputs, so the full
//! EAGL/ALPS pipeline runs and is testable with no AOT artifacts.
//!
//! ## Execution path
//!
//! All compute routes through [`crate::kernels`]: blocked GEMM tiles over
//! transposed quantized weights with preallocated scratch
//! ([`kernels::Workspace`]), a per-layer quantized-weight cache that is
//! invalidated only when a train step rewrites the weights, and a
//! featurizer cache keyed by batch content (deterministic
//! [`crate::data::Dataset::batch`] streams make content identity equal
//! (task, split, index, batch) identity).  Every kernel preserves the
//! reference f32 accumulation order, so the fast path is bit-identical
//! to the scalar loops it replaced — see `rust/benches/perf_hotpath.rs`
//! for the measured speedups and `rust/tests/kernel_cache_parallel.rs`
//! for the identity assertions.
//!
//! ## Proxy models
//!
//! The input is the textures classification task
//! ([`crate::data::Dataset::for_task`] with [`crate::backend::Task::Cls`]); a fixed,
//! parameter-free Gabor-energy featurizer reduces each 32×32×3 image to
//! 10 oriented-grating energies (one per class generator), after which a
//! stack of quantized linear layers discriminates.  Two models ship:
//!
//! * `sim_tiny` — 4 layers, for fast pipeline tests;
//! * `sim_skew` — 6 layers engineered so EAGL's premise *holds by
//!   construction*: a high-entropy `wide` layer carries the main path
//!   (dropping it to 2-bit is destructive), while low-entropy layers
//!   (`idty`, `mix_a`, `mix_b`) are small-gain residual branches whose
//!   2-bit quantization is nearly harmless.  Layer `macs` are skewed so
//!   a mid-range budget forces the knapsack to choose between them.

use std::collections::HashMap;
use std::sync::Arc;

use crate::ckpt::Checkpoint;
use crate::eagl;
use crate::jsonio::Json;
use crate::kernels::packed::{self, PackedNet};
use crate::kernels::{self, FeatCache, GradWs, PackedWeightCache, WeightCache, Workspace};
use crate::quant;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

use super::manifest::Manifest;
use super::{Backend, KernelChoice, SharedExecState};

/// Residual branch gain: out = in + GAMMA * branch(in).
const GAMMA: f32 = 0.05;
/// SGD momentum of the fused train step.
const MOMENTUM: f32 = 0.9;
/// Featurizer output scale (puts class energies at O(1)).
const FEAT_SCALE: f32 = 6.0;
/// Finite-difference step of the Hutchinson v·Hv probe.
const VHV_EPS: f32 = 1e-2;
/// Precision the `eagl_step` entry scores selectable layers at.  Like the
/// AOT artifact (whose entropy graph is lowered at the default `b_hi`),
/// the entry is fixed at 4-bit; fixed layers score at their pinned bits.
/// Callers needing another precision use the native
/// [`crate::eagl::checkpoint_entropies`] directly.
const EAGL_CKPT_BITS: u32 = 4;
/// Image side and feature count of the textures task.
const IMG: usize = 32;
const N_FEATURES: usize = 10;
const N_CLASSES: usize = 10;
/// Featurizer-cache capacity (entries are batch × N_FEATURES f32s).
const FEAT_CACHE_CAP: usize = 64;

/// Static spec of one sim layer.
#[derive(Debug, Clone)]
struct SimLayer {
    name: &'static str,
    fan_in: usize,
    fan_out: usize,
    link_group: &'static str,
    fixed_bits: Option<u32>,
    /// Residual side branch (out = in + GAMMA*layer(in)); needs fan_in == fan_out.
    branch: bool,
    w_sigma: f32,
    sw: f32,
    sa: f32,
    macs: u64,
}

#[allow(clippy::too_many_arguments)]
fn lay(
    name: &'static str,
    fan_in: usize,
    fan_out: usize,
    link_group: &'static str,
    fixed_bits: Option<u32>,
    branch: bool,
    w_sigma: f32,
    sw: f32,
    sa: f32,
    macs: u64,
) -> SimLayer {
    SimLayer {
        name,
        fan_in,
        fan_out,
        link_group,
        fixed_bits,
        branch,
        w_sigma,
        sw,
        sa,
        macs,
    }
}

fn layers_for(model: &str) -> Option<Vec<SimLayer>> {
    match model {
        "sim_tiny" => Some(vec![
            lay("stem", N_FEATURES, 12, "stem", Some(8), false, 0.45, 0.19, 0.10, 120),
            lay("h1", 12, 12, "h1", None, false, 0.30, 0.15, 0.10, 500),
            lay("h2", 12, 12, "h2", None, true, 0.10, 0.20, 0.10, 500),
            lay("head", 12, N_CLASSES, "head", Some(8), false, 0.35, 0.12, 0.10, 120),
        ]),
        "sim_skew" => Some(vec![
            lay("stem", N_FEATURES, 16, "stem", Some(8), false, 0.45, 0.19, 0.10, 160),
            lay("wide", 16, 16, "wide", None, false, 0.35, 0.12, 0.30, 6000),
            lay("idty", 16, 16, "idty", None, true, 0.02, 0.25, 0.10, 400),
            lay("mix_a", 16, 16, "mix", None, true, 0.10, 0.20, 0.10, 400),
            lay("mix_b", 16, 16, "mix", None, true, 0.10, 0.20, 0.10, 400),
            lay("head", 16, N_CLASSES, "head", Some(8), false, 0.35, 0.12, 0.10, 160),
        ]),
        _ => None,
    }
}

/// Names of the available sim models (for error messages / docs).
pub const SIM_MODELS: &[&str] = &["sim_tiny", "sim_skew"];

/// Borrowed per-layer parameter views — the entry points marshal slices
/// straight out of the argument tensors (no per-call clone chain).
struct NetRef<'a> {
    w: &'a [f32],
    b: &'a [f32],
    sw: f32,
    sa: f32,
}

/// Validate and view the per-layer (w, b, sw, sa) parameter tensors.
fn net_refs<'a>(layers: &[SimLayer], params: &[&'a Tensor]) -> crate::Result<Vec<NetRef<'a>>> {
    crate::ensure!(
        params.len() == 4 * layers.len(),
        "sim: expected {} param tensors, got {}",
        4 * layers.len(),
        params.len()
    );
    let mut net = Vec::with_capacity(layers.len());
    for (li, l) in layers.iter().enumerate() {
        let w = params[4 * li];
        let b = params[4 * li + 1];
        crate::ensure!(
            w.len() == l.fan_in * l.fan_out && b.len() == l.fan_out,
            "sim: bad param shape for layer {}",
            l.name
        );
        net.push(NetRef {
            w: w.f32s(),
            b: b.f32s(),
            sw: params[4 * li + 2].item(),
            sa: params[4 * li + 3].item(),
        });
    }
    Ok(net)
}

/// Packed-kernel forward pass ([`crate::kernels::packed`]): identical
/// structure to [`forward_pass`], but every layer executes over
/// bit-packed weight codes instead of materialized f32 fake-quant
/// weights.  Interior layers use the LUT-decode kernel, which preserves
/// the reference accumulation order **bit for bit** — mandatory, because
/// their outputs feed the discontinuous activation quantizer
/// (`round(h/sa)`), where any reassociation could flip a code near a
/// rounding boundary.  The head layer optionally (`head_epilogue`)
/// applies the LSQ scale once in the epilogue instead — the packed
/// inference path's integer-style numerics, safe there because nothing
/// requantizes logits; bounded by [`packed::PACKED_LOGIT_EPS`].
///
/// Codes come from `pinned` (an adopted [`PackedNet`] — the serving
/// engine's share-across-workers path, no re-fingerprinting) when
/// present, else from the per-layer `pcache` memo.
///
/// `tuning` selects the tile variant and the intra-layer row-band width
/// — both inside the kernels' documented contracts, so results here are
/// bit-identical across every variant and thread count (the ε = 0 LUT
/// kernel carries every interior layer; the head epilogue stays within
/// [`packed::PACKED_LOGIT_EPS`] by the same argument at any tuning).
#[allow(clippy::too_many_arguments)]
fn packed_forward(
    layers: &[SimLayer],
    net: &[NetRef<'_>],
    bits_eff: &[u32],
    pcache: &mut PackedWeightCache,
    pinned: Option<&PackedNet>,
    feats: &[f32],
    fwd: &mut Vec<kernels::LayerWs>,
    batch: usize,
    head_epilogue: bool,
    tuning: crate::backend::KernelTuning,
) -> crate::Result<()> {
    let n_layers = layers.len();
    if let Some(pn) = pinned {
        // Fail closed on a precision mismatch: the pinned codes were
        // packed for one bits vector; serving a different one through
        // them would silently execute the wrong quantization.
        crate::ensure!(
            pn.bits_eff == bits_eff,
            "sim: adopted packed codes were materialized for bits {:?}, \
             but this call passes {:?}",
            pn.bits_eff,
            bits_eff
        );
    }
    while fwd.len() < n_layers {
        fwd.push(kernels::LayerWs::default());
    }
    for li in 0..n_layers {
        let (done, rest) = fwd.split_at_mut(li);
        let cur = &mut rest[0];
        let spec = &layers[li];
        let p = &net[li];
        let (fi, fo) = (spec.fan_in, spec.fan_out);
        let a_in: &[f32] = if li == 0 { feats } else { &done[li - 1].out };
        let pk = match pinned {
            Some(pn) => Arc::clone(&pn.layers[li]),
            None => pcache.ensure(li, bits_eff[li], p.sw, p.w, fi, fo)?,
        };
        cur.z.clear();
        cur.z.resize(batch * fo, 0.0);
        if li == n_layers - 1 && head_epilogue {
            packed::gemm_bias_packed_epilogue_v(
                a_in, &pk, p.b, &mut cur.z, batch,
                tuning.variant, tuning.gemm_threads,
            );
        } else {
            packed::gemm_bias_packed_v(
                a_in, &pk, p.b, &mut cur.z, batch,
                tuning.variant, tuning.gemm_threads,
            );
        }
        if li == n_layers - 1 {
            cur.act_in.clear();
            cur.out.clear();
            cur.out.extend_from_slice(&cur.z);
        } else {
            let (_, aqp) = quant::qrange_unsigned(bits_eff[li]);
            cur.act_in.clear();
            cur.act_in.resize(batch * fo, false);
            cur.out.clear();
            cur.out.resize(batch * fo, 0.0);
            let residual = if spec.branch { Some(a_in) } else { None };
            kernels::gemm::relu_quant_act(
                &cur.z,
                p.sa,
                aqp,
                residual,
                GAMMA,
                &mut cur.out,
                &mut cur.act_in,
            );
        }
    }
    Ok(())
}

/// Quantized forward pass through the kernel tiles; activations, masks
/// and logits land in `fwd` (logits = `fwd[last].out`).
fn forward_pass(
    layers: &[SimLayer],
    net: &[NetRef<'_>],
    bits_eff: &[u32],
    wcache: &mut WeightCache,
    feats: &[f32],
    fwd: &mut Vec<kernels::LayerWs>,
    batch: usize,
) {
    let n_layers = layers.len();
    while fwd.len() < n_layers {
        fwd.push(kernels::LayerWs::default());
    }
    for li in 0..n_layers {
        let (done, rest) = fwd.split_at_mut(li);
        let cur = &mut rest[0];
        let spec = &layers[li];
        let p = &net[li];
        let (fi, fo) = (spec.fan_in, spec.fan_out);
        let a_in: &[f32] = if li == 0 { feats } else { &done[li - 1].out };
        let (qn, qp) = quant::qrange_signed(bits_eff[li]);
        let (wt, _) = wcache.ensure(li, bits_eff[li], p.sw, p.w, fi, fo, qn, qp);
        cur.z.clear();
        cur.z.resize(batch * fo, 0.0);
        kernels::gemm::gemm_bias_wt(a_in, wt, p.b, &mut cur.z, batch, fi, fo);
        if li == n_layers - 1 {
            // Head: logits pass through unquantized.
            cur.act_in.clear();
            cur.out.clear();
            cur.out.extend_from_slice(&cur.z);
        } else {
            let (_, aqp) = quant::qrange_unsigned(bits_eff[li]);
            cur.act_in.clear();
            cur.act_in.resize(batch * fo, false);
            cur.out.clear();
            cur.out.resize(batch * fo, 0.0);
            let residual = if spec.branch { Some(a_in) } else { None };
            kernels::gemm::relu_quant_act(
                &cur.z,
                p.sa,
                aqp,
                residual,
                GAMMA,
                &mut cur.out,
                &mut cur.act_in,
            );
        }
    }
}

/// Backward pass with clipped STE; per-layer (dW, db) land in `g`.
/// `d` enters as dlogits and is clobbered.  Relies on the paired
/// [`forward_pass`] having just ensured every layer's quantized weights:
/// they are read back via [`WeightCache::peek`], so the backward half
/// never re-fingerprints a weight tensor.
#[allow(clippy::too_many_arguments)]
fn backward_pass(
    layers: &[SimLayer],
    wcache: &WeightCache,
    feats: &[f32],
    fwd: &[kernels::LayerWs],
    batch: usize,
    d: &mut Vec<f32>,
    d_in: &mut Vec<f32>,
    dbr: &mut Vec<f32>,
    g: &mut GradWs,
) {
    let n_layers = layers.len();
    for li in (0..n_layers).rev() {
        let spec = &layers[li];
        let (fi, fo) = (spec.fan_in, spec.fan_out);
        let last = li == n_layers - 1;
        let cache = &fwd[li];
        // Gradient at the layer's pre-activation output.
        dbr.clear();
        if last {
            dbr.extend_from_slice(d);
        } else {
            dbr.resize(batch * fo, 0.0);
            let scale = if spec.branch { GAMMA } else { 1.0 };
            kernels::gemm::ste_backprop_mask(d, &cache.z, &cache.act_in, scale, dbr);
        }
        let a_in: &[f32] = if li == 0 { feats } else { &fwd[li - 1].out };
        // dW = a_inᵀ · dbr (masked below), db = Σ_b dbr.
        let dw = &mut g.dw[li];
        dw.clear();
        dw.resize(fi * fo, 0.0);
        let db = &mut g.db[li];
        db.clear();
        db.resize(fo, 0.0);
        kernels::gemm::accumulate_grads(a_in, dbr, dw, db, batch, fi, fo);
        let (wt, w_in) = wcache.peek(li);
        kernels::gemm::mask_grads(dw, w_in);
        // d_in = dbr · wqᵀ.
        d_in.clear();
        d_in.resize(batch * fi, 0.0);
        kernels::gemm::gemm_din_wt(dbr, wt, d_in, batch, fi, fo);
        if !last && spec.branch {
            // Skip connection: upstream gradient passes through.
            for (dv, &iv) in d.iter_mut().zip(d_in.iter()) {
                *dv += iv;
            }
        } else {
            std::mem::swap(d, d_in);
        }
    }
}

/// Full forward + backward into the reusable workspaces: per-layer
/// (dW, db) in `g`, returns (mean loss, correct count).
#[allow(clippy::too_many_arguments)]
fn grads_into(
    layers: &[SimLayer],
    net: &[NetRef<'_>],
    bits_eff: &[u32],
    wcache: &mut WeightCache,
    feats: &[f32],
    ws: &mut Workspace,
    g: &mut GradWs,
    y: &[i32],
    batch: usize,
) -> (f32, usize) {
    g.ensure(layers.len());
    forward_pass(layers, net, bits_eff, wcache, feats, &mut ws.fwd, batch);
    let logits = &ws.fwd[layers.len() - 1].out;
    let (loss, correct) =
        kernels::gemm::softmax_ce(logits, y, batch, N_CLASSES, Some(&mut ws.d));
    backward_pass(
        layers, wcache, feats, &ws.fwd, batch, &mut ws.d, &mut ws.d_in, &mut ws.dbr, g,
    );
    (loss, correct)
}

/// The hermetic reference backend.
pub struct SimBackend {
    manifest: Manifest,
    layers: Vec<SimLayer>,
    /// Gabor featurizer basis, [N_FEATURES][IMG*IMG], flattened.
    basis_cos: Vec<f32>,
    basis_sin: Vec<f32>,
    /// Cumulative executions per entry (perf accounting parity with pjrt).
    pub exec_counts: HashMap<String, u64>,
    /// Reusable forward/backward scratch (see [`crate::kernels`]).
    ws: Workspace,
    /// Gradient buffers; two so the vHv probe holds both FD endpoints.
    g0: GradWs,
    g1: GradWs,
    /// Quantized-weight memo, invalidated when a train step updates weights.
    wcache: WeightCache,
    /// Bit-packed weight-code memo (same fingerprint invalidation) for
    /// the packed kernel path.
    pcache: PackedWeightCache,
    /// Featurizer memo keyed by batch content.
    fcache: FeatCache,
    /// Which forward kernels `eval_step`/`infer_step` execute with
    /// (training, vHv and EAGL always run the reference kernels).
    kernel: KernelChoice,
    /// Packed-path tuning: tile variant + intra-layer row-band width.
    /// Result-invisible on the packed eval/infer path (see
    /// [`packed_forward`]); ignored by the reference kernels.
    tuning: crate::backend::KernelTuning,
    /// Adopted shared packed codes (see [`Backend::adopt_shared`]): when
    /// present, the packed path uses them directly instead of
    /// re-fingerprinting the weights per call — serving executes an
    /// immutable checkpoint, so content re-hashing per request is waste.
    packed_pinned: Option<Arc<PackedNet>>,
}

impl SimBackend {
    /// Build the sim backend for one of the [`SIM_MODELS`] with the
    /// default (reference) kernels.
    pub fn new(model: &str) -> crate::Result<SimBackend> {
        SimBackend::with_kernel(model, KernelChoice::Reference)
    }

    /// Build the sim backend with an explicit [`KernelChoice`] and the
    /// default [`crate::backend::KernelTuning`].
    pub fn with_kernel(model: &str, kernel: KernelChoice) -> crate::Result<SimBackend> {
        SimBackend::with_tuning(model, kernel, crate::backend::KernelTuning::default())
    }

    /// Build the sim backend with explicit kernel choice and packed-path
    /// tuning (variant + gemm-threads).
    pub fn with_tuning(
        model: &str,
        kernel: KernelChoice,
        tuning: crate::backend::KernelTuning,
    ) -> crate::Result<SimBackend> {
        let layers = layers_for(model).ok_or_else(|| {
            crate::err!(
                "unknown sim model '{model}' (available: {}); artifact models \
                 need the pjrt backend",
                SIM_MODELS.join(", ")
            )
        })?;
        // Chain consistency (defensive — specs are static).
        for win in layers.windows(2) {
            let carried = if win[1].branch { win[1].fan_out } else { win[1].fan_in };
            crate::ensure!(
                win[0].fan_out == win[1].fan_in && win[1].fan_in == carried,
                "sim model '{model}': fan mismatch {} -> {}",
                win[0].name,
                win[1].name
            );
        }
        let manifest = Manifest::from_json(manifest_json(model, &layers))?;
        let (basis_cos, basis_sin) = featurizer_basis();
        let n_layers = layers.len();
        Ok(SimBackend {
            manifest,
            layers,
            basis_cos,
            basis_sin,
            exec_counts: HashMap::new(),
            ws: Workspace::default(),
            g0: GradWs::default(),
            g1: GradWs::default(),
            wcache: WeightCache::new(n_layers),
            pcache: PackedWeightCache::new(n_layers),
            fcache: FeatCache::new(FEAT_CACHE_CAP),
            kernel,
            tuning,
            packed_pinned: None,
        })
    }

    /// Cache counters, for tests and diagnostics:
    /// (featurizer hits, featurizer misses, weight hits, weight misses).
    pub fn cache_stats(&self) -> (u64, u64, u64, u64) {
        (
            self.fcache.hits,
            self.fcache.misses,
            self.wcache.hits,
            self.wcache.misses,
        )
    }

    /// Packed-code cache counters: (hits, misses).  Calls served by an
    /// adopted [`PackedNet`] touch neither counter.
    pub fn packed_cache_stats(&self) -> (u64, u64) {
        (self.pcache.hits, self.pcache.misses)
    }

    /// Canonical parameter names, 4 per layer: w, b, sw, sa.
    fn param_names(&self) -> Vec<String> {
        let mut names = Vec::with_capacity(4 * self.layers.len());
        for l in &self.layers {
            for suffix in ["w", "b", "sw", "sa"] {
                names.push(format!("{}/{}", l.name, suffix));
            }
        }
        names
    }

    // -- entry implementations ----------------------------------------------

    fn layer_bits(&self, li: usize, bits: &[f32]) -> u32 {
        self.layers[li]
            .fixed_bits
            .unwrap_or_else(|| bits[li].round().max(1.0) as u32)
    }

    /// Effective per-layer precision (fixed layers pinned).
    fn effective_bits(&self, bits: &[f32]) -> Vec<u32> {
        (0..self.layers.len())
            .map(|li| self.layer_bits(li, bits))
            .collect()
    }

    /// Validate the image tensor shape; returns the batch size.
    fn check_x(&self, x: &Tensor) -> crate::Result<usize> {
        crate::ensure!(
            x.shape.len() == 4 && x.shape[1] == IMG && x.shape[2] == IMG && x.shape[3] == 3,
            "sim: expected x of shape [B,{IMG},{IMG},3], got {:?}",
            x.shape
        );
        Ok(x.shape[0])
    }

    /// Gabor-energy featurizer with content-keyed memoization (see
    /// [`crate::kernels::FeatCache`]); returns an index into the cache.
    fn featurize_cached(&mut self, x: &Tensor, batch: usize) -> usize {
        let xs = x.f32s();
        let fp = kernels::fingerprint_f32(xs);
        if let Some(i) = self.fcache.find(fp, xs.len()) {
            return i;
        }
        let mut feats = vec![0f32; batch * N_FEATURES];
        kernels::gemm::gabor_energies(
            xs,
            &self.basis_cos,
            &self.basis_sin,
            &mut self.ws.gray,
            batch,
            IMG * IMG,
            N_FEATURES,
            FEAT_SCALE,
            &mut feats,
        );
        self.fcache.insert(fp, xs.len(), feats)
    }

    fn exec_train(&mut self, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let n = 4 * self.layers.len();
        crate::ensure!(args.len() == 2 * n + 5, "sim train_step: arity {}", args.len());
        let net = net_refs(&self.layers, &args[..n])?;
        let mom_args = &args[n..2 * n];
        let x = args[2 * n];
        let y_t = args[2 * n + 1];
        let lr = args[2 * n + 2].item();
        let wd = args[2 * n + 3].item();
        let bits = args[2 * n + 4].f32s();
        crate::ensure!(bits.len() == self.layers.len(), "sim: bits arity");
        let batch = self.check_x(x)?;
        let y = y_t.i32s();
        crate::ensure!(y.len() == batch, "sim: y arity");
        let bits_eff = self.effective_bits(bits);
        let feats_idx = self.featurize_cached(x, batch);
        let feats = self.fcache.feats(feats_idx);
        let (loss, correct) = grads_into(
            &self.layers,
            &net,
            &bits_eff,
            &mut self.wcache,
            feats,
            &mut self.ws,
            &mut self.g0,
            y,
            batch,
        );
        // SGD momentum update (wd on weights only; step sizes are inert).
        let mut out = Vec::with_capacity(2 * n + 2);
        let mut mom_out = Vec::with_capacity(n);
        for (li, l) in self.layers.iter().enumerate() {
            let p = &net[li];
            let dw = &self.g0.dw[li];
            let db = &self.g0.db[li];
            let mw_old = mom_args[4 * li].f32s();
            let mb_old = mom_args[4 * li + 1].f32s();
            let mut w_new = Vec::with_capacity(p.w.len());
            let mut mw_new = Vec::with_capacity(p.w.len());
            for i in 0..p.w.len() {
                let m = MOMENTUM * mw_old[i] + dw[i] + wd * p.w[i];
                mw_new.push(m);
                w_new.push(p.w[i] - lr * m);
            }
            let mut b_new = Vec::with_capacity(p.b.len());
            let mut mb_new = Vec::with_capacity(p.b.len());
            for o in 0..p.b.len() {
                let m = MOMENTUM * mb_old[o] + db[o];
                mb_new.push(m);
                b_new.push(p.b[o] - lr * m);
            }
            out.push(Tensor::from_f32(&[l.fan_in, l.fan_out], w_new));
            out.push(Tensor::from_f32(&[l.fan_out], b_new));
            out.push((*args[4 * li + 2]).clone()); // sw (inert)
            out.push((*args[4 * li + 3]).clone()); // sa (inert)
            mom_out.push(Tensor::from_f32(&[l.fan_in, l.fan_out], mw_new));
            mom_out.push(Tensor::from_f32(&[l.fan_out], mb_new));
            mom_out.push((*mom_args[4 * li + 2]).clone());
            mom_out.push((*mom_args[4 * li + 3]).clone());
        }
        out.extend(mom_out);
        out.push(Tensor::scalar(loss));
        out.push(Tensor::scalar(correct as f32 / batch as f32));
        Ok(out)
    }

    fn exec_eval(&mut self, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let n = 4 * self.layers.len();
        crate::ensure!(args.len() == n + 3, "sim eval_step: arity {}", args.len());
        let net = net_refs(&self.layers, &args[..n])?;
        let x = args[n];
        let y_t = args[n + 1];
        let bits = args[n + 2].f32s();
        crate::ensure!(bits.len() == self.layers.len(), "sim: bits arity");
        let batch = self.check_x(x)?;
        let y = y_t.i32s();
        crate::ensure!(y.len() == batch, "sim: y arity");
        let bits_eff = self.effective_bits(bits);
        let feats_idx = self.featurize_cached(x, batch);
        let feats = self.fcache.feats(feats_idx);
        // Packed evaluation keeps the head on the LUT kernel too
        // (`head_epilogue = false`), so eval — and everything built on it:
        // ALPS probes, frontier sweeps, `mpq infer` — is bit-identical to
        // the reference kernels by construction.
        match self.kernel {
            KernelChoice::Reference => forward_pass(
                &self.layers,
                &net,
                &bits_eff,
                &mut self.wcache,
                feats,
                &mut self.ws.fwd,
                batch,
            ),
            KernelChoice::Packed => packed_forward(
                &self.layers,
                &net,
                &bits_eff,
                &mut self.pcache,
                self.packed_pinned.as_deref(),
                feats,
                &mut self.ws.fwd,
                batch,
                false,
                self.tuning,
            )?,
        }
        let logits = &self.ws.fwd[self.layers.len() - 1].out;
        let (loss, correct) = kernels::gemm::softmax_ce(logits, y, batch, N_CLASSES, None);
        Ok(vec![
            Tensor::scalar(loss),
            Tensor::from_f32(&[], vec![correct as f32]),
        ])
    }

    /// Inference: per-sample logits `[batch, N_CLASSES]`.  The forward
    /// kernels are row-independent (documented accumulation order in
    /// [`crate::kernels::gemm`]), so each sample's logit row is
    /// bit-identical no matter which batch it rides in — the property the
    /// serving engine's fused micro-batching relies on.
    fn exec_infer(&mut self, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let n = 4 * self.layers.len();
        crate::ensure!(args.len() == n + 2, "sim infer_step: arity {}", args.len());
        let net = net_refs(&self.layers, &args[..n])?;
        let x = args[n];
        let bits = args[n + 1].f32s();
        crate::ensure!(bits.len() == self.layers.len(), "sim: bits arity");
        let batch = self.check_x(x)?;
        let bits_eff = self.effective_bits(bits);
        let feats_idx = self.featurize_cached(x, batch);
        let feats = self.fcache.feats(feats_idx);
        // The packed inference path runs the logits layer with the LSQ
        // scale applied once in the epilogue — nothing requantizes
        // logits, so the reassociation stays within the documented
        // epsilon ([`packed::PACKED_LOGIT_EPS`]) and can never flip an
        // interior activation code.
        match self.kernel {
            KernelChoice::Reference => forward_pass(
                &self.layers,
                &net,
                &bits_eff,
                &mut self.wcache,
                feats,
                &mut self.ws.fwd,
                batch,
            ),
            KernelChoice::Packed => packed_forward(
                &self.layers,
                &net,
                &bits_eff,
                &mut self.pcache,
                self.packed_pinned.as_deref(),
                feats,
                &mut self.ws.fwd,
                batch,
                true,
                self.tuning,
            )?,
        }
        let logits = self.ws.fwd[self.layers.len() - 1].out.clone();
        Ok(vec![Tensor::from_f32(&[batch, N_CLASSES], logits)])
    }

    fn exec_vhv(&mut self, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let n = 4 * self.layers.len();
        crate::ensure!(args.len() == n + 4, "sim vhv_step: arity {}", args.len());
        let net = net_refs(&self.layers, &args[..n])?;
        let x = args[n];
        let y_t = args[n + 1];
        let bits = args[n + 2].f32s();
        crate::ensure!(bits.len() == self.layers.len(), "sim: bits arity");
        let seed = args[n + 3].i32s()[0];
        let batch = self.check_x(x)?;
        let y = y_t.i32s();
        crate::ensure!(y.len() == batch, "sim: y arity");
        let bits_eff = self.effective_bits(bits);
        // Rademacher probe per layer, deterministic in the seed.
        let mut rng = Pcg32::new(seed as u32 as u64, 0x6876_7673);
        let vs: Vec<Vec<f32>> = self
            .layers
            .iter()
            .map(|l| (0..l.fan_in * l.fan_out).map(|_| rng.rademacher()).collect())
            .collect();
        let feats_idx = self.featurize_cached(x, batch);
        let feats = self.fcache.feats(feats_idx);
        grads_into(
            &self.layers,
            &net,
            &bits_eff,
            &mut self.wcache,
            feats,
            &mut self.ws,
            &mut self.g0,
            y,
            batch,
        );
        let w2: Vec<Vec<f32>> = net
            .iter()
            .zip(&vs)
            .map(|(p, v)| {
                let mut w = p.w.to_vec();
                for (wv, &vv) in w.iter_mut().zip(v) {
                    *wv += VHV_EPS * vv;
                }
                w
            })
            .collect();
        let net2: Vec<NetRef<'_>> = net
            .iter()
            .zip(&w2)
            .map(|(p, w)| NetRef {
                w: w.as_slice(),
                b: p.b,
                sw: p.sw,
                sa: p.sa,
            })
            .collect();
        grads_into(
            &self.layers,
            &net2,
            &bits_eff,
            &mut self.wcache,
            feats,
            &mut self.ws,
            &mut self.g1,
            y,
            batch,
        );
        let mut vhv = vec![0f32; self.layers.len()];
        for li in 0..self.layers.len() {
            let (g1w, g0w) = (&self.g1.dw[li], &self.g0.dw[li]);
            let mut acc = 0f64;
            for (i, &vv) in vs[li].iter().enumerate() {
                acc += ((g1w[i] - g0w[i]) / VHV_EPS * vv) as f64;
            }
            vhv[li] = acc as f32;
        }
        Ok(vec![Tensor::from_f32(&[self.layers.len()], vhv)])
    }

    fn exec_eagl(&mut self, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let n_layers = self.layers.len();
        crate::ensure!(args.len() == 2 * n_layers, "sim eagl_step: arity {}", args.len());
        let mut out = vec![0f32; n_layers];
        for (li, l) in self.layers.iter().enumerate() {
            let w = args[2 * li];
            let sw = args[2 * li + 1].item();
            let b_eff = l.fixed_bits.unwrap_or(EAGL_CKPT_BITS);
            out[li] = eagl::layer_entropy(w.f32s(), sw, b_eff)? as f32;
        }
        Ok(vec![Tensor::from_f32(&[n_layers], out)])
    }
}

impl Backend for SimBackend {
    fn kind(&self) -> &'static str {
        "sim"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Deterministic seeded-RNG initial checkpoint: per-layer Gaussian
    /// weights (stream keyed by layer index), zero biases, configured
    /// step sizes.
    fn init_checkpoint(&self) -> crate::Result<Checkpoint> {
        let mut tensors = Vec::with_capacity(4 * self.layers.len());
        for (li, l) in self.layers.iter().enumerate() {
            let mut rng = Pcg32::new(
                0x51AB_0000_0000_0000 ^ (li as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                0x1417,
            );
            let w: Vec<f32> = (0..l.fan_in * l.fan_out)
                .map(|_| l.w_sigma * rng.normal())
                .collect();
            tensors.push(Tensor::from_f32(&[l.fan_in, l.fan_out], w));
            tensors.push(Tensor::zeros(&[l.fan_out]));
            tensors.push(Tensor::from_f32(&[], vec![l.sw]));
            tensors.push(Tensor::from_f32(&[], vec![l.sa]));
        }
        Ok(Checkpoint::new(self.param_names(), tensors))
    }

    /// Materialize the bit-packed weight codes for `(params, bits)` once,
    /// as a shareable [`PackedNet`] — the serving engine hands the Arc to
    /// every worker ([`adopt_shared`](Backend::adopt_shared)) so N
    /// workers pack each layer once, not N times.  `None` on the
    /// reference kernel path (nothing shareable).
    fn prepare_shared(
        &mut self,
        params: &Checkpoint,
        bits: &[f32],
    ) -> crate::Result<Option<SharedExecState>> {
        if self.kernel != KernelChoice::Packed {
            return Ok(None);
        }
        let refs: Vec<&Tensor> = params.tensors.iter().collect();
        let net = net_refs(&self.layers, &refs)?;
        crate::ensure!(bits.len() == self.layers.len(), "sim: bits arity");
        let bits_eff = self.effective_bits(bits);
        let mut packed_layers = Vec::with_capacity(self.layers.len());
        for (li, (spec, p)) in self.layers.iter().zip(&net).enumerate() {
            packed_layers.push(Arc::new(packed::pack(
                p.w,
                p.sw,
                bits_eff[li],
                spec.fan_in,
                spec.fan_out,
            )?));
        }
        let net_pk = Arc::new(PackedNet {
            bits_eff,
            layers: packed_layers,
        });
        self.packed_pinned = Some(Arc::clone(&net_pk));
        Ok(Some(net_pk as SharedExecState))
    }

    /// Adopt a [`PackedNet`] handle.  Ignored on the reference kernel
    /// path (the handle is packed-only state); fails closed when the
    /// handle is not this backend's type or layer count.
    fn adopt_shared(&mut self, state: &SharedExecState) -> crate::Result<()> {
        if self.kernel != KernelChoice::Packed {
            return Ok(());
        }
        let net_pk = Arc::clone(state)
            .downcast::<PackedNet>()
            .map_err(|_| crate::err!("sim: adopt_shared handle is not a PackedNet"))?;
        crate::ensure!(
            net_pk.layers.len() == self.layers.len(),
            "sim: adopted PackedNet has {} layer(s), model '{}' has {}",
            net_pk.layers.len(),
            self.manifest.model,
            self.layers.len()
        );
        self.packed_pinned = Some(net_pk);
        Ok(())
    }

    fn execute(&mut self, entry: &str, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        *self.exec_counts.entry(entry.to_string()).or_insert(0) += 1;
        match entry {
            "train_step" => self.exec_train(args),
            "eval_step" => self.exec_eval(args),
            "infer_step" => self.exec_infer(args),
            "vhv_step" => self.exec_vhv(args),
            "eagl_step" => self.exec_eagl(args),
            other => crate::bail!("sim backend: unknown entry '{other}'"),
        }
    }
}

/// Fixed oriented-grating (Gabor) correlation basis matching the textures
/// generator in [`crate::data`]: one (orientation, frequency) pair per
/// class.
fn featurizer_basis() -> (Vec<f32>, Vec<f32>) {
    let px = IMG * IMG;
    let mut cos_b = vec![0f32; N_FEATURES * px];
    let mut sin_b = vec![0f32; N_FEATURES * px];
    for k in 0..N_FEATURES {
        let (theta, freq) = crate::data::texture_class_params(k);
        let (st, ct) = theta.sin_cos();
        for i in 0..IMG {
            for j in 0..IMG {
                let u = (i as f32 - IMG as f32 / 2.0) / IMG as f32;
                let v = (j as f32 - IMG as f32 / 2.0) / IMG as f32;
                let t = (u * ct + v * st) * freq * std::f32::consts::TAU;
                cos_b[k * px + i * IMG + j] = t.cos();
                sin_b[k * px + i * IMG + j] = t.sin();
            }
        }
    }
    (cos_b, sin_b)
}

/// Synthesize the manifest JSON for a sim model (same schema as the AOT
/// path's `<model>.manifest.json`).
fn manifest_json(model: &str, layers: &[SimLayer]) -> Json {
    let mut params = Vec::new();
    for l in layers {
        params.push(param_spec(l.name, "w", vec![l.fan_in, l.fan_out]));
        params.push(param_spec(l.name, "b", vec![l.fan_out]));
        params.push(param_spec(l.name, "sw", vec![]));
        params.push(param_spec(l.name, "sa", vec![]));
    }
    let layer_rows: Vec<Json> = layers
        .iter()
        .enumerate()
        .map(|(qindex, l)| {
            Json::obj(vec![
                ("name", Json::str(l.name)),
                ("kind", Json::str("linear")),
                ("qindex", Json::num(qindex as f64)),
                ("link_group", Json::str(l.link_group)),
                ("macs", Json::num(l.macs as f64)),
                ("weight_params", Json::num((l.fan_in * l.fan_out) as f64)),
                (
                    "fixed_bits",
                    match l.fixed_bits {
                        Some(b) => Json::num(b as f64),
                        None => Json::Null,
                    },
                ),
            ])
        })
        .collect();
    let entry = |order: &[&str], outputs: &[&str]| {
        Json::obj(vec![
            ("file", Json::str("<sim builtin>")),
            ("order", Json::arr(order.iter().map(|s| Json::str(s)))),
            ("outputs", Json::arr(outputs.iter().map(|s| Json::str(s)))),
        ])
    };
    let entries = Json::obj(vec![
        (
            "train_step",
            entry(
                &["params", "mom", "x", "y", "lr", "wd", "bits"],
                &["params", "mom", "loss", "metric"],
            ),
        ),
        ("eval_step", entry(&["params", "x", "y", "bits"], &["loss", "evalout"])),
        ("infer_step", entry(&["params", "x", "bits"], &["logits"])),
        ("vhv_step", entry(&["params", "x", "y", "bits", "seed"], &["vhv"])),
        ("eagl_step", entry(&["w_sw"], &["entropies"])),
    ]);
    let usizes = |v: &[usize]| Json::arr(v.iter().map(|&d| Json::num(d as f64)));
    let meta = Json::obj(vec![
        ("n_bits", Json::num(layers.len() as f64)),
        ("train_batch", Json::num(16.0)),
        ("eval_batch", Json::num(64.0)),
        ("task", Json::str("cls")),
        ("x_train_shape", usizes(&[16, IMG, IMG, 3])),
        ("y_train_shape", usizes(&[16])),
        ("x_eval_shape", usizes(&[64, IMG, IMG, 3])),
        ("y_eval_shape", usizes(&[64])),
        ("x_dtype", Json::str("float32")),
        ("y_dtype", Json::str("int32")),
        ("evalout_shape", usizes(&[])),
    ]);
    Json::obj(vec![
        ("model", Json::str(model)),
        ("params", Json::Arr(params)),
        ("layers", Json::Arr(layer_rows)),
        ("entries", entries),
        ("meta", meta),
    ])
}

fn param_spec(layer: &str, suffix: &str, shape: Vec<usize>) -> Json {
    Json::obj(vec![
        ("name", Json::str(&format!("{layer}/{suffix}"))),
        ("shape", Json::arr(shape.iter().map(|&d| Json::num(d as f64)))),
        ("dtype", Json::str("float32")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, Split};
    use crate::graph::Graph;
    use crate::quant::BitsConfig;

    #[test]
    fn unknown_model_is_actionable() {
        let err = SimBackend::new("qresnet20").unwrap_err().to_string();
        assert!(err.contains("sim_tiny"), "{err}");
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn manifest_graph_and_checkpoint_are_consistent() {
        for model in SIM_MODELS {
            let be = SimBackend::new(model).unwrap();
            let m = be.manifest();
            assert_eq!(m.model, *model);
            let graph = Graph::from_manifest(&m.raw).unwrap();
            assert_eq!(graph.n_bits(), m.n_bits);
            assert!(!graph.groups.is_empty());
            let ck = be.init_checkpoint().unwrap();
            assert_eq!(ck.names.len(), m.params.len());
            for (name, spec) in ck.names.iter().zip(&m.params) {
                assert_eq!(name, &spec.name);
            }
        }
    }

    #[test]
    fn init_checkpoint_is_deterministic() {
        let be = SimBackend::new("sim_tiny").unwrap();
        let a = be.init_checkpoint().unwrap();
        let b = be.init_checkpoint().unwrap();
        for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
            assert_eq!(ta, tb);
        }
    }

    #[test]
    fn eval_runs_and_counts_correct() {
        let mut be = SimBackend::new("sim_tiny").unwrap();
        let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
        let data = Dataset::for_task(be.manifest().task, 1);
        let ck = be.init_checkpoint().unwrap();
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let batch = be.manifest().eval_batch;
        let (x, y) = data.batch(Split::Eval, 0, batch);
        let (loss, out) = be.eval_step(&ck, &x, &y, &bits).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(out.shape, be.manifest().evalout_shape);
        let correct = out.item();
        assert!((0.0..=batch as f32).contains(&correct), "correct={correct}");
        assert_eq!(be.exec_counts.get("eval_step"), Some(&1));
    }

    #[test]
    fn repeated_eval_hits_caches_with_identical_results() {
        let mut be = SimBackend::new("sim_tiny").unwrap();
        let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
        let data = Dataset::for_task(be.manifest().task, 1);
        let ck = be.init_checkpoint().unwrap();
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let (x, y) = data.batch(Split::Eval, 0, be.manifest().eval_batch);
        let (l1, c1) = be.eval_step(&ck, &x, &y, &bits).unwrap();
        let (l2, c2) = be.eval_step(&ck, &x, &y, &bits).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(c1, c2);
        let (feat_hits, feat_misses, w_hits, _) = be.cache_stats();
        assert_eq!(feat_misses, 1, "second eval must reuse the featurized batch");
        assert!(feat_hits >= 1);
        assert!(w_hits >= graph.layers.len() as u64, "weight codes must be reused");
    }

    #[test]
    fn infer_logits_are_row_independent_and_match_eval() {
        // The serving engine's fused batching hinges on this: a sample's
        // logit row must not depend on the batch it rides in, and a
        // softmax-CE over the rows must reproduce eval_step exactly.
        let mut be = SimBackend::new("sim_tiny").unwrap();
        let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
        let data = Dataset::for_task(be.manifest().task, 5);
        let ck = be.init_checkpoint().unwrap();
        let mut bits = BitsConfig::uniform(&graph, 4);
        // Mixed precisions so the weight cache sees several code sets.
        bits.bits[1] = 2;
        let bits = bits.to_f32();
        let (x, y) = data.batch(Split::Eval, 2, 6);
        let logits = be.infer_step(&ck, &x, &bits).unwrap();
        assert_eq!(logits.shape, vec![6, N_CLASSES]);
        // Row independence: each sample alone produces the same row.
        let row = IMG * IMG * 3;
        for b in 0..6 {
            let xs = x.f32s()[b * row..(b + 1) * row].to_vec();
            let xb = Tensor::from_f32(&[1, IMG, IMG, 3], xs);
            let lb = be.infer_step(&ck, &xb, &bits).unwrap();
            assert_eq!(
                lb.f32s(),
                &logits.f32s()[b * N_CLASSES..(b + 1) * N_CLASSES],
                "sample {b} logits must not depend on batch composition"
            );
        }
        // Host-side softmax-CE over the rows == eval_step on the batch.
        let (loss_ref, out_ref) = be.eval_step(&ck, &x, &y, &bits).unwrap();
        let (loss, correct) =
            crate::kernels::gemm::softmax_ce(logits.f32s(), y.i32s(), 6, N_CLASSES, None);
        assert_eq!(loss.to_bits(), loss_ref.to_bits());
        assert_eq!(correct as f32, out_ref.item());
    }

    #[test]
    fn packed_eval_is_bit_identical_and_caches_codes() {
        for model in SIM_MODELS {
            let mut rbe = SimBackend::new(model).unwrap();
            let mut pbe = SimBackend::with_kernel(model, KernelChoice::Packed).unwrap();
            let graph = Graph::from_manifest(&rbe.manifest().raw).unwrap();
            let data = Dataset::for_task(rbe.manifest().task, 3);
            let ck = rbe.init_checkpoint().unwrap();
            let mut bits = BitsConfig::uniform(&graph, 4);
            bits.bits[1] = 2; // a genuinely mixed assignment
            let bits = bits.to_f32();
            let (x, y) = data.batch(Split::Eval, 0, 32);
            let (lr, cr) = rbe.eval_step(&ck, &x, &y, &bits).unwrap();
            let (lp, cp) = pbe.eval_step(&ck, &x, &y, &bits).unwrap();
            assert_eq!(lp.to_bits(), lr.to_bits(), "{model}: packed eval loss must be bit-identical");
            assert_eq!(cp, cr, "{model}: packed eval correct-count must be identical");
            // A second eval over the frozen checkpoint reuses the packed codes.
            pbe.eval_step(&ck, &x, &y, &bits).unwrap();
            let (hits, misses) = pbe.packed_cache_stats();
            assert_eq!(misses, graph.layers.len() as u64);
            assert!(hits >= graph.layers.len() as u64);
        }
    }

    #[test]
    fn prepared_packed_codes_are_adopted_and_fail_closed_on_bits_mismatch() {
        let mut owner = SimBackend::with_kernel("sim_tiny", KernelChoice::Packed).unwrap();
        let graph = Graph::from_manifest(&owner.manifest().raw).unwrap();
        let data = Dataset::for_task(owner.manifest().task, 3);
        let ck = owner.init_checkpoint().unwrap();
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let (x, _) = data.batch(Split::Eval, 1, 5);
        let handle = owner.prepare_shared(&ck, &bits).unwrap().expect("packed state");
        // An adopter serves straight off the shared codes: identical
        // logits, zero packed-cache traffic.
        let mut adopter = SimBackend::with_kernel("sim_tiny", KernelChoice::Packed).unwrap();
        adopter.adopt_shared(&handle).unwrap();
        let la = adopter.infer_step(&ck, &x, &bits).unwrap();
        let mut solo = SimBackend::with_kernel("sim_tiny", KernelChoice::Packed).unwrap();
        let ls = solo.infer_step(&ck, &x, &bits).unwrap();
        assert_eq!(la, ls);
        assert_eq!(adopter.packed_cache_stats(), (0, 0));
        assert_eq!(solo.packed_cache_stats().1, graph.layers.len() as u64);
        // Serving a different precision vector through adopted codes is
        // an error, not a silent wrong-quantization execution.
        let bits2 = BitsConfig::uniform(&graph, 2).to_f32();
        let err = adopter.infer_step(&ck, &x, &bits2).unwrap_err().to_string();
        assert!(err.contains("packed codes"), "{err}");
        // The reference kernel path has nothing to share.
        let mut rbe = SimBackend::new("sim_tiny").unwrap();
        assert!(rbe.prepare_shared(&ck, &bits).unwrap().is_none());
    }

    #[test]
    fn skew_init_entropies_are_ordered() {
        // The engineered premise: wide ≫ mix layers ≫ idty at init.
        let mut be = SimBackend::new("sim_skew").unwrap();
        let ck = be.init_checkpoint().unwrap();
        let ents = be.eagl_step(&ck).unwrap();
        let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
        let h = |name: &str| {
            let l = graph.layers.iter().find(|l| l.name == name).unwrap();
            ents[l.qindex] as f64
        };
        assert!(h("wide") > 3.0, "wide H = {}", h("wide"));
        assert!(h("idty") < 0.5, "idty H = {}", h("idty"));
        assert!(h("mix_a") + h("mix_b") < h("wide"), "mix group must stay below wide");
    }
}
