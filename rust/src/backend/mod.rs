//! Pluggable execution backends — the seam between the selection pipeline
//! and whatever actually runs the network.
//!
//! [`Backend`] abstracts execution: a backend exposes its [`Manifest`]
//! (entry points, shapes, layer table), an initial [`Checkpoint`], and a
//! single `execute(entry, inputs) -> outputs` primitive.  The typed entry
//! points the pipeline uses (`train_step`, `eval_step`, `vhv_step`,
//! `eagl_step`) are provided methods built on `execute`, so every backend
//! shares one marshaling convention:
//!
//! ```text
//! train_step: params.. mom.. x y lr wd bits  ->  params'.. mom'.. loss metric
//! eval_step:  params.. x y bits              ->  loss evalout
//! infer_step: params.. x bits                ->  per-sample logits
//! vhv_step:   params.. x y bits seed         ->  per-layer v·Hv
//! eagl_step:  (w, sw per layer)              ->  per-layer entropies
//! ```
//!
//! Implementations:
//!
//! * [`SimBackend`] (always available) — hermetic pure-Rust reference
//!   executor over synthesized proxy models; see [`sim`].
//! * `PjrtBackend` (`--features pjrt`) — executes AOT-lowered HLO-text
//!   artifacts through a PJRT CPU client; see `pjrt`.
//!
//! [`resolve`] + [`open`] implement the CLI's `--backend sim|pjrt|auto`
//! selection; `auto` prefers artifacts when they exist *and* the pjrt
//! backend is compiled in, else falls back to sim.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sim;

use crate::ckpt::Checkpoint;
use crate::tensor::Tensor;

pub use crate::kernels::packed::PackedVariant;
pub use manifest::{EntrySpec, Manifest, Task, TensorSpec};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use sim::SimBackend;

/// Opaque, immutable, cheaply cloneable execution state a backend
/// materializes once and many backend instances adopt — e.g. the sim
/// backend's bit-packed weight codes ([`crate::kernels::packed::PackedNet`]),
/// which the serving engine packs once and shares across all N workers.
/// `Any` keeps the [`Backend`] trait object-safe and backend-agnostic;
/// each implementation downcasts to its own concrete type.
pub type SharedExecState = std::sync::Arc<dyn std::any::Any + Send + Sync>;

/// Which forward-kernel implementation a backend executes inference and
/// evaluation with (`--kernel` on the CLI).
///
/// * `Reference` — fake-quant f32 GEMM over materialized `wt = code·sw`
///   weights; the authoritative numerics.
/// * `Packed` — bit-packed integer weight codes
///   ([`crate::kernels::packed`]): interior layers decode through a LUT
///   in the reference accumulation order (bit-identical), the logits
///   layer applies the LSQ scale once in the epilogue (documented
///   epsilon).  Training, vHv and EAGL entries always run the reference
///   kernels — only `eval_step`/`infer_step` route through packed codes.
///
/// Sim-only: the pjrt artifact path executes lowered HLO as-is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelChoice {
    #[default]
    Reference,
    Packed,
}

impl KernelChoice {
    pub fn name(self) -> &'static str {
        match self {
            KernelChoice::Reference => "reference",
            KernelChoice::Packed => "packed",
        }
    }

    pub fn parse(s: &str) -> crate::Result<KernelChoice> {
        match s {
            "reference" => Ok(KernelChoice::Reference),
            "packed" => Ok(KernelChoice::Packed),
            other => crate::bail!("unknown kernel '{other}' (expected packed|reference)"),
        }
    }
}

/// How the packed kernels execute — which [`PackedVariant`] tile set and
/// how many intra-layer GEMM row-band threads.  Orthogonal to
/// [`KernelChoice`]: tuning only takes effect on the packed path, and
/// every combination satisfies the same accuracy contracts (variants are
/// bit-identical on the ε = 0 kernels, row bands bit-identical at any
/// width — see [`crate::kernels::packed`]).  Sim-only, like the packed
/// kernels themselves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelTuning {
    pub variant: PackedVariant,
    /// Row-parallel width for the packed GEMMs.  Keep 1 inside serve
    /// workers (the engine already runs one worker per core); `mpq
    /// infer`/eval paths default wider.
    pub gemm_threads: usize,
}

impl Default for KernelTuning {
    fn default() -> KernelTuning {
        KernelTuning { variant: PackedVariant::default(), gemm_threads: 1 }
    }
}

/// Mutable fine-tune state: parameters and SGD momenta, in manifest order.
#[derive(Clone)]
pub struct TrainState {
    pub params: Checkpoint,
    pub mom: Checkpoint,
}

impl TrainState {
    pub fn new(params: Checkpoint) -> TrainState {
        let mom = params.zeros_like();
        TrainState { params, mom }
    }
}

/// An execution backend. Object-safe: the coordinator and CLI run over
/// `Box<dyn Backend>` while tests can use concrete types.
pub trait Backend {
    /// Short backend name ("sim" | "pjrt") for logs and reports.
    fn kind(&self) -> &'static str;

    /// The model contract: entry points, shapes, layer table, task.
    fn manifest(&self) -> &Manifest;

    /// The model's initial (seed-0) checkpoint.
    fn init_checkpoint(&self) -> crate::Result<Checkpoint>;

    /// Execute an entry point with host tensors; returns decomposed outputs.
    fn execute(&mut self, entry: &str, args: &[&Tensor]) -> crate::Result<Vec<Tensor>>;

    /// Force-compile an entry (warmup / startup-cost measurement).
    /// No-op for backends without a compile step.
    fn compile_entry(&mut self, entry: &str) -> crate::Result<()> {
        let _ = entry;
        Ok(())
    }

    /// Pre-materialize immutable shared execution state for serving
    /// `(params, bits)` — e.g. the sim backend's bit-packed weight codes
    /// — as a handle other instances of the same configuration can
    /// [`adopt_shared`](Backend::adopt_shared), so an N-worker engine
    /// pays the materialization once instead of N times.  `None` (the
    /// default) when the backend has nothing shareable for its current
    /// kernel configuration.
    fn prepare_shared(
        &mut self,
        params: &Checkpoint,
        bits: &[f32],
    ) -> crate::Result<Option<SharedExecState>> {
        let _ = (params, bits);
        Ok(None)
    }

    /// Adopt a [`prepare_shared`](Backend::prepare_shared) handle
    /// produced by a backend of the same model and kernel configuration.
    /// The adopted state is trusted to match the `(params, bits)` of
    /// every subsequent call that uses it — the serving engine guarantees
    /// this by construction (one immutable checkpoint + bits vector per
    /// engine); per-layer precisions are still cross-checked fail-closed.
    fn adopt_shared(&mut self, state: &SharedExecState) -> crate::Result<()> {
        let _ = state;
        Ok(())
    }

    // -- typed entry points (shared marshaling over `execute`) --------------

    /// One fused SGD fine-tune step.  Updates `state` in place and returns
    /// (loss, train metric).
    fn train_step(
        &mut self,
        state: &mut TrainState,
        x: &Tensor,
        y: &Tensor,
        lr: f32,
        wd: f32,
        bits: &[f32],
    ) -> crate::Result<(f32, f32)> {
        let n = self.manifest().n_params();
        let lr_t = Tensor::scalar(lr);
        let wd_t = Tensor::scalar(wd);
        let bits_t = Tensor::from_f32(&[bits.len()], bits.to_vec());
        let mut args: Vec<&Tensor> = Vec::with_capacity(2 * n + 5);
        args.extend(state.params.tensors.iter());
        args.extend(state.mom.tensors.iter());
        args.extend([x, y, &lr_t, &wd_t, &bits_t]);
        let mut out = self.execute("train_step", &args)?;
        drop(args);
        crate::ensure!(out.len() == 2 * n + 2, "train_step output arity");
        let metric = out.pop().unwrap().item();
        let loss = out.pop().unwrap().item();
        let mom_new = out.split_off(n);
        state.params = Checkpoint::new(state.params.names.clone(), out);
        state.mom = Checkpoint::new(state.mom.names.clone(), mom_new);
        Ok((loss, metric))
    }

    /// Evaluation step: returns (mean loss over batch, task-specific
    /// accumulator tensor — see [`Task`]).
    fn eval_step(
        &mut self,
        params: &Checkpoint,
        x: &Tensor,
        y: &Tensor,
        bits: &[f32],
    ) -> crate::Result<(f32, Tensor)> {
        let bits_t = Tensor::from_f32(&[bits.len()], bits.to_vec());
        let mut args: Vec<&Tensor> = Vec::with_capacity(params.tensors.len() + 3);
        args.extend(params.tensors.iter());
        args.extend([x, y, &bits_t]);
        let mut out = self.execute("eval_step", &args)?;
        crate::ensure!(out.len() == 2, "eval_step output arity");
        let evalout = out.pop().unwrap();
        let loss = out.pop().unwrap().item();
        Ok((loss, evalout))
    }

    /// Inference entry: per-sample logits `[batch, classes]` — the fused
    /// serving path ([`crate::serve`]) batches many requests' samples into
    /// one call and reassembles per-request results from the rows.  Only
    /// available when the manifest lists an `infer_step` entry (the sim
    /// backend always does; artifact sets lowered without it make the
    /// serving engine fall back to per-request `eval_step`).
    fn infer_step(
        &mut self,
        params: &Checkpoint,
        x: &Tensor,
        bits: &[f32],
    ) -> crate::Result<Tensor> {
        let bits_t = Tensor::from_f32(&[bits.len()], bits.to_vec());
        let mut args: Vec<&Tensor> = Vec::with_capacity(params.tensors.len() + 2);
        args.extend(params.tensors.iter());
        args.extend([x, &bits_t]);
        let mut out = self.execute("infer_step", &args)?;
        crate::ensure!(out.len() == 1, "infer_step output arity");
        Ok(out.pop().unwrap())
    }

    /// One Hutchinson sample: per-layer v·Hv vector (HAWQ-v3 trace).
    fn vhv_step(
        &mut self,
        params: &Checkpoint,
        x: &Tensor,
        y: &Tensor,
        bits: &[f32],
        seed: i32,
    ) -> crate::Result<Vec<f32>> {
        let bits_t = Tensor::from_f32(&[bits.len()], bits.to_vec());
        let seed_t = Tensor::from_i32(&[1], vec![seed]);
        let mut args: Vec<&Tensor> = Vec::with_capacity(params.tensors.len() + 4);
        args.extend(params.tensors.iter());
        args.extend([x, y, &bits_t, &seed_t]);
        let out = self.execute("vhv_step", &args)?;
        crate::ensure!(out.len() == 1, "vhv_step output arity");
        Ok(out[0].f32s().to_vec())
    }

    /// Per-layer EAGL entropies computed by the backend (cross-check path
    /// for the native rust implementation in [`crate::eagl`]).
    ///
    /// Only each layer's `w` and `sw` enter the entry signature (in the
    /// original flatten order) — marshal exactly those.
    fn eagl_step(&mut self, params: &Checkpoint) -> crate::Result<Vec<f32>> {
        let args: Vec<&Tensor> = params
            .names
            .iter()
            .zip(&params.tensors)
            .filter(|(n, _)| n.ends_with("/w") || n.ends_with("/sw"))
            .map(|(_, t)| t)
            .collect();
        let out = self.execute("eagl_step", &args)?;
        crate::ensure!(out.len() == 1, "eagl_step output arity");
        Ok(out[0].f32s().to_vec())
    }
}

/// A source of fresh [`Backend`] instances for parallel fan-out: each
/// worker thread of [`crate::coordinator::job_pool`] opens its own
/// backend (PJRT clients are not Sync; sim backends carry per-instance
/// caches).  Any `Fn() -> Result<B>` closure is a factory — the blanket
/// impl below — so call sites pass `&|| SimBackend::new("sim_skew")` or
/// the coordinator's boxed re-opener.
///
/// Because every backend of the same model computes deterministically
/// and instance-independently, work fanned out over factory-opened
/// instances is bit-identical to running it sequentially on one
/// instance (asserted in `rust/tests/kernel_cache_parallel.rs`).
pub trait BackendFactory: Sync {
    type B: Backend;
    fn open(&self) -> crate::Result<Self::B>;
}

impl<B: Backend, F: Fn() -> crate::Result<B> + Sync> BackendFactory for F {
    type B = B;
    fn open(&self) -> crate::Result<B> {
        self()
    }
}

impl Backend for Box<dyn Backend> {
    fn kind(&self) -> &'static str {
        (**self).kind()
    }
    fn manifest(&self) -> &Manifest {
        (**self).manifest()
    }
    fn init_checkpoint(&self) -> crate::Result<Checkpoint> {
        (**self).init_checkpoint()
    }
    fn execute(&mut self, entry: &str, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        (**self).execute(entry, args)
    }
    fn compile_entry(&mut self, entry: &str) -> crate::Result<()> {
        (**self).compile_entry(entry)
    }
    fn prepare_shared(
        &mut self,
        params: &Checkpoint,
        bits: &[f32],
    ) -> crate::Result<Option<SharedExecState>> {
        (**self).prepare_shared(params, bits)
    }
    fn adopt_shared(&mut self, state: &SharedExecState) -> crate::Result<()> {
        (**self).adopt_shared(state)
    }
}

/// Which backend to open.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Sim,
    Pjrt,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Sim => "sim",
            BackendKind::Pjrt => "pjrt",
        }
    }

    pub fn parse(s: &str) -> crate::Result<BackendKind> {
        match s {
            "sim" => Ok(BackendKind::Sim),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => crate::bail!("unknown backend '{other}' (expected sim|pjrt|auto)"),
        }
    }
}

/// Resolve `--backend` (None or "auto" = automatic): pjrt when artifacts
/// for `model` exist *and* the pjrt backend is compiled in, else sim.
pub fn resolve(requested: Option<&str>, model: &str) -> crate::Result<BackendKind> {
    match requested {
        None | Some("auto") => {
            let has_artifacts = crate::find_artifacts()
                .map(|d| d.join(format!("{model}.manifest.json")).is_file())
                .unwrap_or(false);
            if has_artifacts && cfg!(feature = "pjrt") {
                Ok(BackendKind::Pjrt)
            } else {
                Ok(BackendKind::Sim)
            }
        }
        Some(s) => BackendKind::parse(s),
    }
}

/// Open a backend for `model` with the default (reference) kernels.
pub fn open(kind: BackendKind, model: &str) -> crate::Result<Box<dyn Backend>> {
    open_with(kind, model, KernelChoice::Reference)
}

/// Open a backend for `model` with an explicit [`KernelChoice`] and the
/// default [`KernelTuning`].  The packed kernels are sim-only; requesting
/// them on pjrt fails closed.
pub fn open_with(
    kind: BackendKind,
    model: &str,
    kernel: KernelChoice,
) -> crate::Result<Box<dyn Backend>> {
    open_tuned(kind, model, kernel, KernelTuning::default())
}

/// Open a backend with explicit kernel choice *and* tuning
/// (variant + gemm-threads).  Tuning only affects the sim packed path;
/// pjrt keeps the reference-only gate.
pub fn open_tuned(
    kind: BackendKind,
    model: &str,
    kernel: KernelChoice,
    tuning: KernelTuning,
) -> crate::Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Sim => Ok(Box::new(SimBackend::with_tuning(model, kernel, tuning)?)),
        BackendKind::Pjrt => {
            crate::ensure!(
                kernel == KernelChoice::Reference,
                "--kernel packed is only available on the sim backend (the pjrt \
                 artifact path executes AOT-lowered HLO as-is); use --kernel reference"
            );
            open_pjrt(model)
        }
    }
}

#[cfg(feature = "pjrt")]
fn open_pjrt(model: &str) -> crate::Result<Box<dyn Backend>> {
    Ok(Box::new(PjrtBackend::load(&crate::artifacts_dir(), model)?))
}

#[cfg(not(feature = "pjrt"))]
fn open_pjrt(_model: &str) -> crate::Result<Box<dyn Backend>> {
    crate::bail!(
        "backend 'pjrt' unavailable: this build has no `pjrt` feature \
         (it needs the vendored `xla` crate — see rust/Cargo.toml). \
         Use `--backend sim` for the hermetic reference backend, or rebuild \
         with `cargo build --features pjrt`."
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_round_trip() {
        for k in [BackendKind::Sim, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(k.name()).unwrap(), k);
        }
        assert!(BackendKind::parse("tpu").is_err());
    }

    #[test]
    fn resolve_defaults_to_sim_without_artifacts() {
        // No artifacts dir for this model name in the test environment.
        let kind = resolve(None, "no_such_model_xyz").unwrap();
        assert_eq!(kind, BackendKind::Sim);
        assert_eq!(resolve(Some("auto"), "no_such_model_xyz").unwrap(), BackendKind::Sim);
        assert_eq!(resolve(Some("sim"), "anything").unwrap(), BackendKind::Sim);
        assert_eq!(resolve(Some("pjrt"), "anything").unwrap(), BackendKind::Pjrt);
        assert!(resolve(Some("bogus"), "m").is_err());
    }

    #[test]
    fn kernel_choice_parse_and_pjrt_gating() {
        for k in [KernelChoice::Reference, KernelChoice::Packed] {
            assert_eq!(KernelChoice::parse(k.name()).unwrap(), k);
        }
        assert!(KernelChoice::parse("int8").is_err());
        assert_eq!(KernelChoice::default(), KernelChoice::Reference);
        // Packed kernels are sim-only: pjrt + packed fails closed with an
        // actionable message, before any artifact lookup.
        let err = open_with(BackendKind::Pjrt, "qresnet20", KernelChoice::Packed)
            .unwrap_err()
            .to_string();
        assert!(err.contains("sim backend"), "{err}");
        // Sim opens with either kernel.
        assert!(open_with(BackendKind::Sim, "sim_tiny", KernelChoice::Packed).is_ok());
    }

    #[test]
    fn kernel_tuning_defaults_and_open_tuned() {
        let d = KernelTuning::default();
        assert_eq!(d.variant, PackedVariant::Unrolled);
        assert_eq!(d.gemm_threads, 1);
        // Tuned open works for both kernels on sim.
        let t = KernelTuning { variant: PackedVariant::Scalar, gemm_threads: 2 };
        assert!(open_tuned(BackendKind::Sim, "sim_tiny", KernelChoice::Packed, t).is_ok());
        assert!(open_tuned(BackendKind::Sim, "sim_tiny", KernelChoice::Reference, t).is_ok());
    }

    #[test]
    fn boxed_backend_forwards() {
        let mut be: Box<dyn Backend> = open(BackendKind::Sim, "sim_tiny").unwrap();
        assert_eq!(be.kind(), "sim");
        assert!(be.manifest().n_params() > 0);
        assert!(be.compile_entry("train_step").is_ok());
        let ck = be.init_checkpoint().unwrap();
        assert_eq!(ck.names.len(), be.manifest().n_params());
    }
}
