//! Typed view over a model manifest — the contract between a backend and
//! the coordinator (input/output ordering, shapes, dtypes, layer table,
//! task metadata).  The pjrt backend reads
//! `artifacts/<model>.manifest.json` emitted by the Python AOT path; the
//! sim backend synthesizes an equivalent manifest in memory.

use std::path::{Path, PathBuf};

use crate::jsonio::{self, Json};
use crate::tensor::DType;

/// Shape + dtype + pytree-path name of one executable input/param.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One lowered entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: String,
    /// Logical argument blocks, in order (e.g. ["params","mom","x","y",...]).
    pub order: Vec<String>,
    /// Logical output blocks, in order.
    pub outputs: Vec<String>,
}

/// Task kind — decides metric accumulation semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Image classification: eval out = correct count.
    Cls,
    /// Semantic segmentation: eval out = (2, C) intersection/union counts.
    Seg,
    /// Span extraction: eval out = (B, 2) predicted start/end.
    Span,
}

/// Parsed manifest for one model.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    pub params: Vec<TensorSpec>,
    pub entries: std::collections::BTreeMap<String, EntrySpec>,
    pub raw: Json,
    pub n_bits: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub task: Task,
    pub x_train_shape: Vec<usize>,
    pub y_train_shape: Vec<usize>,
    pub x_eval_shape: Vec<usize>,
    pub y_eval_shape: Vec<usize>,
    pub x_dtype: DType,
    pub y_dtype: DType,
    pub evalout_shape: Vec<usize>,
}

/// Path of a model's manifest inside an artifacts dir, with an actionable
/// error (names the expected path and the `MPQ_ARTIFACTS` override) when
/// it does not exist — instead of failing deep inside manifest parsing.
pub fn manifest_path_checked(artifacts: &Path, model: &str) -> crate::Result<PathBuf> {
    let path = artifacts.join(format!("{model}.manifest.json"));
    if !path.is_file() {
        crate::bail!(
            "no AOT artifacts for model '{model}': expected manifest at {} — \
             build them (`make artifacts`), point MPQ_ARTIFACTS at the \
             artifacts directory, or use the hermetic sim backend \
             (`--backend sim`, models sim_tiny/sim_skew)",
            path.display()
        );
    }
    Ok(path)
}

impl Manifest {
    pub fn load(artifacts: &Path, model: &str) -> crate::Result<Manifest> {
        let path = manifest_path_checked(artifacts, model)?;
        let raw = jsonio::parse_file(&path)?;
        Self::from_json(raw)
    }

    pub fn from_json(raw: Json) -> crate::Result<Manifest> {
        let model = raw
            .at(&["model"])
            .as_str()
            .ok_or_else(|| crate::err!("manifest: missing model"))?
            .to_string();
        let mut params = Vec::new();
        for spec in raw.at(&["params"]).as_arr().unwrap_or(&[]) {
            params.push(TensorSpec {
                name: spec.at(&["name"]).as_str().unwrap_or_default().to_string(),
                shape: spec.at(&["shape"]).usize_vec(),
                dtype: DType::from_numpy(spec.at(&["dtype"]).as_str().unwrap_or("float32"))?,
            });
        }
        let mut entries = std::collections::BTreeMap::new();
        if let Some(map) = raw.at(&["entries"]).as_obj() {
            for (name, e) in map {
                entries.insert(
                    name.clone(),
                    EntrySpec {
                        file: e.at(&["file"]).as_str().unwrap_or_default().to_string(),
                        order: e
                            .at(&["order"])
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|v| v.as_str().map(String::from))
                            .collect(),
                        outputs: e
                            .at(&["outputs"])
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|v| v.as_str().map(String::from))
                            .collect(),
                    },
                );
            }
        }
        let meta = raw.at(&["meta"]);
        let task = match meta.at(&["task"]).as_str() {
            Some("cls") => Task::Cls,
            Some("seg") => Task::Seg,
            Some("span") => Task::Span,
            other => crate::bail!("manifest: unknown task {other:?}"),
        };
        Ok(Manifest {
            model,
            params,
            entries,
            n_bits: meta.at(&["n_bits"]).as_usize().unwrap_or(0),
            train_batch: meta.at(&["train_batch"]).as_usize().unwrap_or(0),
            eval_batch: meta.at(&["eval_batch"]).as_usize().unwrap_or(0),
            task,
            x_train_shape: meta.at(&["x_train_shape"]).usize_vec(),
            y_train_shape: meta.at(&["y_train_shape"]).usize_vec(),
            x_eval_shape: meta.at(&["x_eval_shape"]).usize_vec(),
            y_eval_shape: meta.at(&["y_eval_shape"]).usize_vec(),
            x_dtype: DType::from_numpy(meta.at(&["x_dtype"]).as_str().unwrap_or("float32"))?,
            y_dtype: DType::from_numpy(meta.at(&["y_dtype"]).as_str().unwrap_or("int32"))?,
            evalout_shape: meta.at(&["evalout_shape"]).usize_vec(),
            raw,
        })
    }

    pub fn entry(&self, name: &str) -> crate::Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| crate::err!("manifest {}: no entry '{name}'", self.model))
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let raw = jsonio::parse(
            r#"{
          "model": "toy",
          "params": [{"name":"a/w","shape":[2,2],"dtype":"float32"}],
          "entries": {"eval_step": {"file":"toy_eval_step.hlo.txt",
                        "order":["params","x","y","bits"],
                        "outputs":["loss","evalout"]}},
          "layers": [],
          "meta": {"n_bits": 3, "train_batch": 4, "eval_batch": 8,
                   "task": "cls", "x_train_shape": [4,8,8,3],
                   "y_train_shape": [4], "x_eval_shape": [8,8,8,3],
                   "y_eval_shape": [8], "x_dtype": "float32",
                   "y_dtype": "int32", "evalout_shape": []}
        }"#,
        )
        .unwrap();
        let m = Manifest::from_json(raw).unwrap();
        assert_eq!(m.model, "toy");
        assert_eq!(m.n_bits, 3);
        assert_eq!(m.task, Task::Cls);
        assert_eq!(m.params[0].shape, vec![2, 2]);
        let e = m.entry("eval_step").unwrap();
        assert_eq!(e.order, vec!["params", "x", "y", "bits"]);
        assert!(m.entry("missing").is_err());
    }

    #[test]
    fn missing_artifacts_error_is_actionable() {
        let dir = std::path::Path::new("/definitely/not/an/artifacts/dir");
        let err = Manifest::load(dir, "qresnet20").unwrap_err().to_string();
        assert!(
            err.contains("/definitely/not/an/artifacts/dir/qresnet20.manifest.json"),
            "error must name the expected path: {err}"
        );
        assert!(err.contains("MPQ_ARTIFACTS"), "error must name the override: {err}");
        assert!(err.contains("--backend sim"), "error must point at the sim fallback: {err}");
    }
}
