//! PjrtBackend — the AOT-artifact execution path (`--features pjrt`).
//!
//! Loads AOT-compiled HLO-text artifacts and executes them through a PJRT
//! CPU client.  Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()`
//! → `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.
//!
//! Executables are compiled once per (model, entry) and cached.  The
//! lowered graphs return a single tuple (`return_tuple=True`), which we
//! decompose on the host.  The typed entry points (`train_step`,
//! `eval_step`, ...) come from the [`Backend`] trait's shared marshaling.

use std::collections::HashMap;
use std::path::PathBuf;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::ckpt::Checkpoint;
use crate::tensor::{Data, Tensor};

use super::manifest::{manifest_path_checked, Manifest};
use super::Backend;

/// A loaded model: PJRT client + manifest + lazily compiled entry points.
pub struct PjrtBackend {
    client: PjRtClient,
    pub manifest: Manifest,
    artifacts: PathBuf,
    exes: HashMap<String, PjRtLoadedExecutable>,
    /// Cumulative executions per entry (perf accounting).
    pub exec_counts: HashMap<String, u64>,
}

impl PjrtBackend {
    /// Load a model's manifest and create a CPU PJRT client.  Entry points
    /// compile lazily on first use (compilation is seconds per entry).
    pub fn load(artifacts: &std::path::Path, model: &str) -> crate::Result<PjrtBackend> {
        // Actionable error before any parsing when artifacts are absent.
        manifest_path_checked(artifacts, model)?;
        let manifest = Manifest::load(artifacts, model)?;
        let client = PjRtClient::cpu().map_err(to_err)?;
        Ok(PjrtBackend {
            client,
            manifest,
            artifacts: artifacts.to_path_buf(),
            exes: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    fn exe(&mut self, entry: &str) -> crate::Result<&PjRtLoadedExecutable> {
        if !self.exes.contains_key(entry) {
            let spec = self.manifest.entry(entry)?.clone();
            let path = self.artifacts.join(&spec.file);
            let proto = HloModuleProto::from_text_file(&path).map_err(to_err)?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(to_err)?;
            self.exes.insert(entry.to_string(), exe);
        }
        Ok(&self.exes[entry])
    }

    // -- marshaling ----------------------------------------------------------

    fn literal_of(&self, t: &Tensor) -> crate::Result<Literal> {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        let lit = match &t.data {
            Data::F32(v) => Literal::vec1(v.as_slice()),
            Data::I32(v) => Literal::vec1(v.as_slice()),
        };
        lit.reshape(&dims).map_err(to_err)
    }

    fn tensor_of(&self, lit: &Literal) -> crate::Result<Tensor> {
        let shape = lit.array_shape().map_err(to_err)?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::from_f32(
                &dims,
                lit.to_vec::<f32>().map_err(to_err)?,
            )),
            xla::ElementType::S32 => Ok(Tensor::from_i32(
                &dims,
                lit.to_vec::<i32>().map_err(to_err)?,
            )),
            other => crate::bail!("unsupported output element type {other:?}"),
        }
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load the model's AOT-emitted initial checkpoint (seed 0).
    fn init_checkpoint(&self) -> crate::Result<Checkpoint> {
        Checkpoint::load(&self.artifacts.join(format!("{}_init.ckpt", self.manifest.model)))
    }

    /// Force-compile an entry (for startup-cost measurement / warmup).
    fn compile_entry(&mut self, entry: &str) -> crate::Result<()> {
        self.exe(entry).map(|_| ())
    }

    /// Execute an entry point with host tensors; returns decomposed outputs.
    fn execute(&mut self, entry: &str, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let mut literals = Vec::with_capacity(args.len());
        for t in args {
            literals.push(self.literal_of(t)?);
        }
        *self.exec_counts.entry(entry.to_string()).or_insert(0) += 1;
        let exe = self.exe(entry)?;
        let result = exe.execute::<Literal>(&literals).map_err(to_err)?;
        let out = result[0][0].to_literal_sync().map_err(to_err)?;
        // return_tuple=True → single tuple output; decompose.
        let parts = out.to_tuple().map_err(to_err)?;
        let mut tensors = Vec::with_capacity(parts.len());
        for lit in &parts {
            tensors.push(self.tensor_of(lit)?);
        }
        Ok(tensors)
    }
}

fn to_err(e: xla::Error) -> crate::error::Error {
    crate::err!("xla: {e}")
}
