//! Declarative experiment layer: manifest → plan → resumable schedule.
//!
//! The paper's evaluation framework (Fig. 1) is a matrix — models ×
//! methods × budgets × seeds.  This subsystem expresses that matrix as a
//! versioned JSON manifest ([`spec`]), expands it deterministically into
//! content-addressed run keys ([`plan`]), dedups them against the
//! per-model JSONL registry ([`registry`]) and fans the remaining runs out
//! over worker-owned backends ([`schedule`]), bit-identical to sequential
//! execution at any worker count.
//!
//! `mpq exp --manifest m.json` is the primary CLI entry point; `mpq run`
//! and `mpq sweep` are thin wrappers that synthesize a one-model spec.

pub mod plan;
pub mod registry;
pub mod schedule;
pub mod spec;

pub use plan::{expand, Plan, RunKey};
pub use registry::Registry;
pub use schedule::{execute, ExecOptions, ExecOutcome};
pub use spec::{ExperimentSpec, ModelSpec, Overrides, RunParams, MANIFEST_VERSION};
