//! Multi-model result registry: one [`ResultStore`] per model, addressed
//! by [`RunKey`].
//!
//! The registry reuses the per-model `sweep.jsonl` layout the single-model
//! CLI always wrote (`<results>/<model>/sweep.jsonl`), so `mpq exp`
//! resumes sweeps started by `mpq sweep` and vice versa — there is exactly
//! one store per model, whatever entry point filled it.

use std::path::PathBuf;

use crate::coordinator::{ResultStore, RunRecord};

use super::plan::RunKey;

pub struct Registry {
    /// (model, store) in spec order; the store for model `m` lives at the
    /// path given at open time (canonically `results_dir_for(kind, m)`).
    stores: Vec<(String, ResultStore)>,
}

impl Registry {
    /// Open one store per (model, store path).  Missing files are fine —
    /// they open empty and are created on first append.
    pub fn open(stores: Vec<(String, PathBuf)>) -> crate::Result<Registry> {
        let mut out = Vec::with_capacity(stores.len());
        for (model, path) in stores {
            crate::ensure!(
                !out.iter().any(|(m, _): &(String, ResultStore)| *m == model),
                "registry: duplicate model \"{model}\""
            );
            out.push((model, ResultStore::open(&path)?));
        }
        Ok(Registry { stores: out })
    }

    fn store(&self, model: &str) -> Option<&ResultStore> {
        self.stores.iter().find(|(m, _)| m == model).map(|(_, s)| s)
    }

    /// Exact-key membership (budget compared by f64 bits).
    pub fn contains(&self, key: &RunKey) -> bool {
        self.store(&key.model)
            .map(|s| s.contains(&key.model, key.method.name(), key.budget_frac, key.seed))
            .unwrap_or(false)
    }

    pub fn find(&self, key: &RunKey) -> Option<RunRecord> {
        self.store(&key.model)?
            .find_exact(&key.model, key.method.name(), key.budget_frac, key.seed)
    }

    /// Append a record to its model's store.
    pub fn append(&mut self, rec: &RunRecord) -> crate::Result<()> {
        let store = self
            .stores
            .iter_mut()
            .find(|(m, _)| *m == rec.model)
            .map(|(_, s)| s)
            .ok_or_else(|| crate::err!("registry: no store for model \"{}\"", rec.model))?;
        store.append(rec)
    }

    /// Records of one model (empty slice when the model is unknown).
    pub fn records(&self, model: &str) -> &[RunRecord] {
        self.store(model).map(|s| s.records()).unwrap_or(&[])
    }

    /// Models in registry (spec) order.
    pub fn models(&self) -> impl Iterator<Item = &str> + '_ {
        self.stores.iter().map(|(m, _)| m.as_str())
    }

    /// Total rows across all stores.
    pub fn len(&self) -> usize {
        self.stores.iter().map(|(_, s)| s.records().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::MethodKind;

    fn rec(model: &str, seed: u64) -> RunRecord {
        RunRecord {
            model: model.into(),
            method: "eagl".into(),
            budget_frac: 0.7,
            seed,
            metric: 0.9,
            loss: 0.1,
            groups_at_lo: 1,
            compression: 8.0,
            gbops: 1.0,
            wall_s: 0.0,
        }
    }

    fn key(model: &str, seed: u64) -> RunKey {
        RunKey {
            model: model.into(),
            method: MethodKind::Eagl,
            budget_frac: 0.7,
            seed,
        }
    }

    #[test]
    fn routes_by_model_and_reopens() {
        let dir = std::env::temp_dir().join(format!("mpq_registry_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let paths = vec![
            ("a".to_string(), dir.join("a/sweep.jsonl")),
            ("b".to_string(), dir.join("b/sweep.jsonl")),
        ];
        let mut reg = Registry::open(paths.clone()).unwrap();
        assert!(reg.is_empty());
        reg.append(&rec("a", 0)).unwrap();
        reg.append(&rec("b", 1)).unwrap();
        assert_eq!(reg.len(), 2);
        assert!(reg.contains(&key("a", 0)));
        assert!(!reg.contains(&key("a", 1)));
        assert!(reg.contains(&key("b", 1)));
        assert_eq!(reg.records("a").len(), 1);
        // Unknown model: no store, append errors, lookups are empty.
        assert!(reg.append(&rec("zzz", 0)).is_err());
        assert!(!reg.contains(&key("zzz", 0)));
        // Reopen resumes both stores from disk.
        let reg2 = Registry::open(paths).unwrap();
        assert_eq!(reg2.len(), 2);
        assert_eq!(reg2.find(&key("b", 1)).unwrap().seed, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
