//! Versioned, declarative experiment manifests — the paper's evaluation
//! matrix (Fig. 1: models × methods × budgets × seeds) as one JSON file.
//!
//! ```json
//! {
//!   "version": 1,
//!   "name": "frontier",
//!   "backend": "sim",
//!   "data_seed": 7,
//!   "models": [
//!     {"name": "sim_tiny", "ft_steps": 80},
//!     "sim_skew"
//!   ],
//!   "methods": ["eagl", "alps", "uniform"],
//!   "budgets": [0.9, 0.7],
//!   "seeds": 2,
//!   "defaults": {"base_steps": 400, "ft_steps": 150, "eval_batches": 4}
//! }
//! ```
//!
//! Parsing is **fail-closed** (SNIPPETS §2 idiom): unknown keys are
//! rejected with a typo suggestion, and every validation error names the
//! offending key path (`models[1].ft_steps: expected a positive integer`).
//! `models` entries are either bare names or objects carrying per-model
//! overrides of the tuning knobs in [`Overrides`]; `seeds` is either an
//! integer count (`2` → seeds `[0, 1]`) or an explicit list.

use std::path::Path;

use crate::backend::Backend;
use crate::coordinator::Coordinator;
use crate::jsonio::{self, Json};
use crate::methods::MethodKind;

/// The manifest version this build reads.
pub const MANIFEST_VERSION: u32 = 1;

/// Per-run tuning knobs a manifest may override, globally (`defaults`)
/// or per model.  `None` = inherit the next layer down.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Overrides {
    pub base_steps: Option<usize>,
    pub ft_steps: Option<usize>,
    pub eval_batches: Option<usize>,
    pub alps_steps: Option<usize>,
    pub hawq_samples: Option<usize>,
    pub hawq_batches: Option<usize>,
    pub workers: Option<usize>,
}

const OVERRIDE_KEYS: &[&str] = &[
    "base_steps",
    "ft_steps",
    "eval_batches",
    "alps_steps",
    "hawq_samples",
    "hawq_batches",
    "workers",
];

impl Overrides {
    fn from_obj(v: &Json, path: &str) -> crate::Result<Overrides> {
        Ok(Overrides {
            base_steps: opt_pos_usize(v, "base_steps", path)?,
            ft_steps: opt_pos_usize(v, "ft_steps", path)?,
            eval_batches: opt_pos_usize(v, "eval_batches", path)?,
            alps_steps: opt_pos_usize(v, "alps_steps", path)?,
            hawq_samples: opt_pos_usize(v, "hawq_samples", path)?,
            hawq_batches: opt_pos_usize(v, "hawq_batches", path)?,
            workers: opt_pos_usize(v, "workers", path)?,
        })
    }
}

/// Fully resolved run parameters for one model (defaults ← manifest
/// `defaults` ← per-model overrides).  Base values mirror
/// [`Coordinator::with_backend`]'s defaults so a manifest that overrides
/// nothing behaves exactly like the bare CLI.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunParams {
    pub base_steps: usize,
    pub ft_steps: usize,
    pub eval_batches: usize,
    pub alps_steps: usize,
    pub hawq_samples: usize,
    pub hawq_batches: usize,
    /// Gain-estimation fan-out for this model's prepare phase; `None` =
    /// the scheduler's worker count.
    pub workers: Option<usize>,
}

impl RunParams {
    pub fn standard() -> RunParams {
        RunParams {
            base_steps: 400,
            ft_steps: 150,
            eval_batches: 4,
            alps_steps: 40,
            hawq_samples: 4,
            hawq_batches: 2,
            workers: None,
        }
    }

    fn overridden(&self, o: &Overrides) -> RunParams {
        RunParams {
            base_steps: o.base_steps.unwrap_or(self.base_steps),
            ft_steps: o.ft_steps.unwrap_or(self.ft_steps),
            eval_batches: o.eval_batches.unwrap_or(self.eval_batches),
            alps_steps: o.alps_steps.unwrap_or(self.alps_steps),
            hawq_samples: o.hawq_samples.unwrap_or(self.hawq_samples),
            hawq_batches: o.hawq_batches.unwrap_or(self.hawq_batches),
            workers: o.workers.or(self.workers),
        }
    }

    /// Push the resolved knobs onto a coordinator.
    pub fn apply<B: Backend>(&self, co: &mut Coordinator<B>) {
        co.base_steps = self.base_steps;
        co.ft_steps = self.ft_steps;
        co.eval_batches = self.eval_batches;
        co.mcfg.alps_steps = self.alps_steps;
        co.mcfg.hawq_samples = self.hawq_samples;
        co.mcfg.hawq_batches = self.hawq_batches;
        if let Some(w) = self.workers {
            co.workers = w.max(1);
        }
    }
}

/// One model row of the matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub overrides: Overrides,
}

/// A parsed, validated experiment manifest.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    pub name: String,
    /// `sim` | `pjrt` | `auto` (`None` = auto); resolved per model at
    /// schedule time.
    pub backend: Option<String>,
    pub data_seed: u64,
    pub models: Vec<ModelSpec>,
    pub methods: Vec<MethodKind>,
    pub budgets: Vec<f64>,
    pub seeds: Vec<u64>,
    pub defaults: Overrides,
}

const TOP_KEYS: &[&str] = &[
    "version", "name", "backend", "data_seed", "models", "methods", "budgets", "seeds", "defaults",
];

impl ExperimentSpec {
    /// Parse + validate a manifest file; errors are prefixed with the path.
    pub fn from_file(path: &Path) -> crate::Result<ExperimentSpec> {
        let v = jsonio::parse_file(path)?;
        Self::from_json(&v).map_err(|e| crate::err!("{}: {e}", path.display()))
    }

    /// Parse + validate a manifest value.  Every error names the offending
    /// key (with a suggestion for likely typos) so a broken 50-cell sweep
    /// fails in milliseconds, not after the first hour of fine-tuning.
    pub fn from_json(v: &Json) -> crate::Result<ExperimentSpec> {
        let obj = v
            .as_obj()
            .ok_or_else(|| crate::err!("manifest: expected a JSON object at the top level"))?;
        reject_unknown_keys(obj.keys().map(|k| k.as_str()), TOP_KEYS, "manifest")?;

        let version = req_pos_usize(v, "version", "manifest")?;
        crate::ensure!(
            version == MANIFEST_VERSION as usize,
            "manifest: version: this build reads manifest v{MANIFEST_VERSION}, got {version}"
        );

        let name = match v.get("name") {
            None => "experiment".to_string(),
            Some(n) => n
                .as_str()
                .ok_or_else(|| crate::err!("manifest: name: expected a string"))?
                .to_string(),
        };

        let backend = match v.get("backend") {
            None => None,
            Some(b) => {
                let s = b
                    .as_str()
                    .ok_or_else(|| crate::err!("manifest: backend: expected a string"))?;
                crate::ensure!(
                    matches!(s, "sim" | "pjrt" | "auto"),
                    "manifest: backend: expected sim|pjrt|auto, got \"{s}\""
                );
                Some(s.to_string())
            }
        };

        let data_seed = match v.get("data_seed") {
            None => 7,
            Some(s) => int_u64(s, "data_seed", "manifest")?,
        };

        let models = parse_models(v)?;
        let methods = parse_methods(v)?;
        let budgets = parse_budgets(v)?;
        let seeds = parse_seeds(v)?;
        let defaults = match v.get("defaults") {
            None => Overrides::default(),
            Some(d) => {
                let dobj = d
                    .as_obj()
                    .ok_or_else(|| crate::err!("manifest: defaults: expected an object"))?;
                reject_unknown_keys(dobj.keys().map(|k| k.as_str()), OVERRIDE_KEYS, "defaults")?;
                Overrides::from_obj(d, "defaults")?
            }
        };

        Ok(ExperimentSpec {
            name,
            backend,
            data_seed,
            models,
            methods,
            budgets,
            seeds,
            defaults,
        })
    }

    /// Synthesize a spec for the thin CLI wrappers (`mpq run` / `mpq
    /// sweep` are one-model manifests the user never has to write).
    pub fn synthesized(
        name: &str,
        backend: Option<String>,
        data_seed: u64,
        model: &str,
        methods: Vec<MethodKind>,
        budgets: Vec<f64>,
        seeds: Vec<u64>,
        defaults: Overrides,
    ) -> ExperimentSpec {
        ExperimentSpec {
            name: name.to_string(),
            backend,
            data_seed,
            models: vec![ModelSpec {
                name: model.to_string(),
                overrides: Overrides::default(),
            }],
            methods,
            budgets,
            seeds,
            defaults,
        }
    }

    /// Resolved run parameters for one model of this spec.
    pub fn params_for(&self, model: &str) -> RunParams {
        let base = RunParams::standard().overridden(&self.defaults);
        match self.models.iter().find(|m| m.name == model) {
            Some(m) => base.overridden(&m.overrides),
            None => base,
        }
    }

    /// Matrix size (runs this spec describes).
    pub fn n_cells(&self) -> usize {
        self.models.len() * self.methods.len() * self.budgets.len() * self.seeds.len()
    }
}

// -- field parsers -----------------------------------------------------------

fn reject_unknown_keys<'a>(
    keys: impl Iterator<Item = &'a str>,
    allowed: &[&str],
    path: &str,
) -> crate::Result<()> {
    for k in keys {
        if !allowed.contains(&k) {
            let hint = match crate::cli::closest(k, allowed.iter().copied()) {
                Some(s) => format!(" (did you mean \"{s}\"?)"),
                None => String::new(),
            };
            crate::bail!(
                "{path}: unknown key \"{k}\"{hint}; allowed: {}",
                allowed.join(", ")
            );
        }
    }
    Ok(())
}

/// A JSON number that is a non-negative integer, as u64.
fn int_u64(v: &Json, key: &str, path: &str) -> crate::Result<u64> {
    let n = v
        .as_f64()
        .ok_or_else(|| crate::err!("{path}: {key}: expected an integer"))?;
    crate::ensure!(
        n.fract() == 0.0 && n >= 0.0 && n <= u64::MAX as f64,
        "{path}: {key}: expected a non-negative integer, got {n}"
    );
    Ok(n as u64)
}

fn req_pos_usize(v: &Json, key: &str, path: &str) -> crate::Result<usize> {
    match v.get(key) {
        None => crate::bail!("{path}: missing required key \"{key}\""),
        Some(n) => pos_usize(n, key, path),
    }
}

fn opt_pos_usize(v: &Json, key: &str, path: &str) -> crate::Result<Option<usize>> {
    v.get(key).map(|n| pos_usize(n, key, path)).transpose()
}

fn pos_usize(n: &Json, key: &str, path: &str) -> crate::Result<usize> {
    let n = int_u64(n, key, path)?;
    crate::ensure!(n >= 1, "{path}: {key}: expected a positive integer, got 0");
    Ok(n as usize)
}

fn parse_models(v: &Json) -> crate::Result<Vec<ModelSpec>> {
    let arr = v
        .get("models")
        .ok_or_else(|| crate::err!("manifest: missing required key \"models\""))?
        .as_arr()
        .ok_or_else(|| crate::err!("manifest: models: expected an array"))?;
    crate::ensure!(!arr.is_empty(), "manifest: models: must not be empty");
    let model_keys: Vec<&str> = std::iter::once("name").chain(OVERRIDE_KEYS.iter().copied()).collect();
    let mut out = Vec::with_capacity(arr.len());
    for (i, m) in arr.iter().enumerate() {
        let path = format!("models[{i}]");
        let spec = match m {
            Json::Str(name) => ModelSpec {
                name: name.clone(),
                overrides: Overrides::default(),
            },
            Json::Obj(obj) => {
                reject_unknown_keys(obj.keys().map(|k| k.as_str()), &model_keys, &path)?;
                let name = m
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| crate::err!("{path}: missing required key \"name\""))?;
                ModelSpec {
                    name: name.to_string(),
                    overrides: Overrides::from_obj(m, &path)?,
                }
            }
            _ => crate::bail!("{path}: expected a model name string or an object"),
        };
        crate::ensure!(!spec.name.is_empty(), "{path}: name: must not be empty");
        crate::ensure!(
            !out.iter().any(|o: &ModelSpec| o.name == spec.name),
            "{path}: duplicate model \"{}\"",
            spec.name
        );
        out.push(spec);
    }
    Ok(out)
}

fn parse_methods(v: &Json) -> crate::Result<Vec<MethodKind>> {
    let arr = v
        .get("methods")
        .ok_or_else(|| crate::err!("manifest: missing required key \"methods\""))?
        .as_arr()
        .ok_or_else(|| crate::err!("manifest: methods: expected an array"))?;
    crate::ensure!(!arr.is_empty(), "manifest: methods: must not be empty");
    let mut out = Vec::with_capacity(arr.len());
    for (i, m) in arr.iter().enumerate() {
        let s = m
            .as_str()
            .ok_or_else(|| crate::err!("methods[{i}]: expected a method name string"))?;
        let kind = MethodKind::parse(s).map_err(|e| crate::err!("methods[{i}]: {e}"))?;
        crate::ensure!(
            kind != MethodKind::Oracle,
            "methods[{i}]: \"oracle\" needs externally supplied gains and cannot run from a manifest"
        );
        crate::ensure!(!out.contains(&kind), "methods[{i}]: duplicate method \"{}\"", kind.name());
        out.push(kind);
    }
    Ok(out)
}

fn parse_budgets(v: &Json) -> crate::Result<Vec<f64>> {
    let arr = v
        .get("budgets")
        .ok_or_else(|| crate::err!("manifest: missing required key \"budgets\""))?
        .as_arr()
        .ok_or_else(|| crate::err!("manifest: budgets: expected an array of fractions"))?;
    crate::ensure!(!arr.is_empty(), "manifest: budgets: must not be empty");
    let mut out: Vec<f64> = Vec::with_capacity(arr.len());
    for (i, b) in arr.iter().enumerate() {
        let f = b
            .as_f64()
            .ok_or_else(|| crate::err!("budgets[{i}]: expected a number"))?;
        crate::ensure!(
            f.is_finite() && f > 0.0 && f <= 1.0,
            "budgets[{i}]: expected a fraction in (0, 1], got {f}"
        );
        crate::ensure!(
            !out.iter().any(|o| o.to_bits() == f.to_bits()),
            "budgets[{i}]: duplicate budget {f}"
        );
        out.push(f);
    }
    Ok(out)
}

fn parse_seeds(v: &Json) -> crate::Result<Vec<u64>> {
    match v.get("seeds") {
        None => crate::bail!("manifest: missing required key \"seeds\""),
        // Integer count: `"seeds": 3` ⇒ seeds [0, 1, 2].
        Some(n @ Json::Num(_)) => {
            let count = int_u64(n, "seeds", "manifest")?;
            crate::ensure!(
                (1..=100_000).contains(&count),
                "manifest: seeds: count must be in 1..=100000, got {count}"
            );
            Ok((0..count).collect())
        }
        Some(Json::Arr(arr)) => {
            crate::ensure!(!arr.is_empty(), "manifest: seeds: must not be empty");
            let mut out = Vec::with_capacity(arr.len());
            for (i, s) in arr.iter().enumerate() {
                let seed = int_u64(s, &format!("seeds[{i}]"), "manifest")
                    .map_err(|_| crate::err!("seeds[{i}]: expected a non-negative integer"))?;
                crate::ensure!(!out.contains(&seed), "seeds[{i}]: duplicate seed {seed}");
                out.push(seed);
            }
            Ok(out)
        }
        Some(_) => crate::bail!("manifest: seeds: expected an integer count or an array of seeds"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> crate::Result<ExperimentSpec> {
        ExperimentSpec::from_json(&jsonio::parse(text).unwrap())
    }

    const GOOD: &str = r#"{
        "version": 1,
        "name": "frontier",
        "backend": "sim",
        "models": [{"name": "sim_tiny", "ft_steps": 80}, "sim_skew"],
        "methods": ["eagl", "alps", "uniform"],
        "budgets": [0.9, 0.7],
        "seeds": 2,
        "defaults": {"base_steps": 100, "eval_batches": 2, "workers": 4}
    }"#;

    #[test]
    fn parses_good_manifest() {
        let spec = parse(GOOD).unwrap();
        assert_eq!(spec.name, "frontier");
        assert_eq!(spec.backend.as_deref(), Some("sim"));
        assert_eq!(spec.models.len(), 2);
        assert_eq!(spec.models[1].name, "sim_skew");
        assert_eq!(spec.methods.len(), 3);
        assert_eq!(spec.budgets, vec![0.9, 0.7]);
        assert_eq!(spec.seeds, vec![0, 1]);
        assert_eq!(spec.n_cells(), 2 * 3 * 2 * 2);
    }

    #[test]
    fn params_layer_defaults_then_model_overrides() {
        let spec = parse(GOOD).unwrap();
        let tiny = spec.params_for("sim_tiny");
        // From defaults:
        assert_eq!(tiny.base_steps, 100);
        assert_eq!(tiny.eval_batches, 2);
        assert_eq!(tiny.workers, Some(4));
        // Model override wins over the standard value:
        assert_eq!(tiny.ft_steps, 80);
        // sim_skew takes defaults + standard.
        let skew = spec.params_for("sim_skew");
        assert_eq!(skew.ft_steps, RunParams::standard().ft_steps);
        assert_eq!(skew.base_steps, 100);
    }

    #[test]
    fn explicit_seed_list() {
        let spec = parse(
            r#"{"version":1,"models":["m"],"methods":["eagl"],"budgets":[0.5],"seeds":[3,1,4]}"#,
        )
        .unwrap();
        assert_eq!(spec.seeds, vec![3, 1, 4]);
        assert_eq!(spec.data_seed, 7);
        assert!(spec.backend.is_none());
    }

    /// Every broken manifest fails with an error naming the offending key.
    #[test]
    fn validation_errors_name_the_key() {
        let cases: &[(&str, &str)] = &[
            (r#"{"models":["m"],"methods":["eagl"],"budgets":[0.5],"seeds":1}"#, "version"),
            (
                r#"{"version":2,"models":["m"],"methods":["eagl"],"budgets":[0.5],"seeds":1}"#,
                "version",
            ),
            (r#"{"version":1,"methods":["eagl"],"budgets":[0.5],"seeds":1}"#, "models"),
            (
                r#"{"version":1,"models":[],"methods":["eagl"],"budgets":[0.5],"seeds":1}"#,
                "models",
            ),
            (
                r#"{"version":1,"models":[{"ft_steps":3}],"methods":["eagl"],"budgets":[0.5],"seeds":1}"#,
                "models[0]",
            ),
            (
                r#"{"version":1,"models":[{"name":"m","ft_step":3}],"methods":["eagl"],"budgets":[0.5],"seeds":1}"#,
                "ft_steps",
            ),
            (
                r#"{"version":1,"models":["m","m"],"methods":["eagl"],"budgets":[0.5],"seeds":1}"#,
                "models[1]",
            ),
            (
                r#"{"version":1,"models":["m"],"methods":["bogus"],"budgets":[0.5],"seeds":1}"#,
                "methods[0]",
            ),
            (
                r#"{"version":1,"models":["m"],"methods":["oracle"],"budgets":[0.5],"seeds":1}"#,
                "methods[0]",
            ),
            (
                r#"{"version":1,"models":["m"],"methods":["eagl"],"budgets":[1.5],"seeds":1}"#,
                "budgets[0]",
            ),
            (
                r#"{"version":1,"models":["m"],"methods":["eagl"],"budgets":[0.5,0.5],"seeds":1}"#,
                "budgets[1]",
            ),
            (
                r#"{"version":1,"models":["m"],"methods":["eagl"],"budgets":[0.5],"seeds":0}"#,
                "seeds",
            ),
            (
                r#"{"version":1,"models":["m"],"methods":["eagl"],"budgets":[0.5],"seeds":[1,1]}"#,
                "seeds[1]",
            ),
            (
                r#"{"version":1,"models":["m"],"methods":["eagl"],"budgets":[0.5],"seeds":1,"defaults":{"ft_steps":0}}"#,
                "ft_steps",
            ),
            (
                r#"{"version":1,"models":["m"],"methods":["eagl"],"budgets":[0.5],"seeds":1,"budgetz":[1]}"#,
                "budgetz",
            ),
            (
                r#"{"version":1,"backend":"tpu","models":["m"],"methods":["eagl"],"budgets":[0.5],"seeds":1}"#,
                "backend",
            ),
        ];
        for (text, key) in cases {
            let err = parse(text).unwrap_err().to_string();
            assert!(err.contains(key), "expected '{key}' in error for {text}: {err}");
        }
    }

    #[test]
    fn unknown_top_level_key_suggests_fix() {
        let err = parse(
            r#"{"version":1,"models":["m"],"methods":["eagl"],"budgets":[0.5],"seeds":1,"budgest":[1]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("did you mean \"budgets\"?"), "{err}");
    }
}
