//! Deterministic plan expansion: a manifest's matrix becomes an ordered
//! list of content-addressed [`RunKey`]s.
//!
//! The order is model-major (model → method → budget → seed), exactly the
//! order the manifest declares each axis, so the same spec always expands
//! to the same run list — and therefore the same JSONL append order at any
//! worker count.  [`Plan::split_pending`] dedups the expansion against the
//! result registry so a killed sweep resumes by skipping completed keys.

use std::collections::HashSet;

use crate::coordinator::RunRecord;
use crate::methods::MethodKind;

use super::registry::Registry;
use super::spec::ExperimentSpec;

/// Identity of one experiment cell.  `fingerprint()` content-addresses it
/// over the model name, method name, the budget's exact f64 bits, and the
/// seed — two keys collide only if every field is identical.
#[derive(Debug, Clone, PartialEq)]
pub struct RunKey {
    pub model: String,
    pub method: MethodKind,
    pub budget_frac: f64,
    pub seed: u64,
}

impl RunKey {
    /// FNV-1a (64-bit) over the canonical field encoding.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        eat(self.model.as_bytes());
        eat(&[0]);
        eat(self.method.name().as_bytes());
        eat(&[0]);
        eat(&self.budget_frac.to_bits().to_le_bytes());
        eat(&self.seed.to_le_bytes());
        h
    }

    pub fn hex(&self) -> String {
        format!("{:016x}", self.fingerprint())
    }

    /// One-line human form for progress output.
    pub fn label(&self) -> String {
        format!(
            "{} {} b={:.2} s={}",
            self.model,
            self.method.name(),
            self.budget_frac,
            self.seed
        )
    }
}

/// The expanded, ordered run list of one spec.
#[derive(Debug, Clone)]
pub struct Plan {
    pub runs: Vec<RunKey>,
}

/// Expand a spec's matrix in declaration order (deterministic).
///
/// Duplicate cells collapse to one run (first occurrence wins).  Parsed
/// manifests reject duplicate axis values outright, but the CLI wrappers
/// synthesize specs from free-form flags (`mpq sweep --budgets 0.9,0.9`)
/// — without the dedup those would fine-tune the same cell twice and
/// append two identical rows.
pub fn expand(spec: &ExperimentSpec) -> Plan {
    let mut seen: HashSet<(String, &'static str, u64, u64)> = HashSet::new();
    let mut runs = Vec::with_capacity(spec.n_cells());
    for model in &spec.models {
        for &method in &spec.methods {
            for &budget_frac in &spec.budgets {
                for &seed in &spec.seeds {
                    let cell =
                        (model.name.clone(), method.name(), budget_frac.to_bits(), seed);
                    if !seen.insert(cell) {
                        continue;
                    }
                    runs.push(RunKey {
                        model: model.name.clone(),
                        method,
                        budget_frac,
                        seed,
                    });
                }
            }
        }
    }
    Plan { runs }
}

impl Plan {
    /// Split the plan against a registry: `(pending, completed)`, both
    /// carrying the run's plan index so results can be merged back into
    /// plan order after the pending set executes.
    pub fn split_pending(
        &self,
        registry: &Registry,
    ) -> (Vec<(usize, RunKey)>, Vec<(usize, RunRecord)>) {
        let mut pending = Vec::new();
        let mut completed = Vec::new();
        for (i, key) in self.runs.iter().enumerate() {
            match registry.find(key) {
                Some(rec) => completed.push((i, rec)),
                None => pending.push((i, key.clone())),
            }
        }
        (pending, completed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;

    fn spec() -> ExperimentSpec {
        ExperimentSpec::from_json(
            &jsonio::parse(
                r#"{
                "version": 1,
                "models": ["sim_tiny", "sim_skew"],
                "methods": ["eagl", "uniform"],
                "budgets": [0.9, 0.7],
                "seeds": 2
            }"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn expansion_is_deterministic_and_model_major() {
        let s = spec();
        let a = expand(&s);
        let b = expand(&s);
        assert_eq!(a.runs.len(), 2 * 2 * 2 * 2);
        assert_eq!(a.runs, b.runs);
        let fp_a: Vec<u64> = a.runs.iter().map(RunKey::fingerprint).collect();
        let fp_b: Vec<u64> = b.runs.iter().map(RunKey::fingerprint).collect();
        assert_eq!(fp_a, fp_b);
        // Model-major: first half is all sim_tiny, in method→budget→seed order.
        assert!(a.runs[..8].iter().all(|r| r.model == "sim_tiny"));
        assert_eq!(a.runs[0].seed, 0);
        assert_eq!(a.runs[1].seed, 1);
        assert_eq!(a.runs[0].budget_frac, 0.9);
        assert_eq!(a.runs[2].budget_frac, 0.7);
        assert_eq!(a.runs[4].method, MethodKind::Uniform);
    }

    #[test]
    fn duplicate_cells_collapse_to_one_run() {
        // Synthesized specs (CLI wrappers) skip manifest validation, so
        // `mpq sweep --budgets 0.9,0.9 --methods eagl,eagl` reaches
        // expansion with duplicate axis values.
        let s = ExperimentSpec::synthesized(
            "dup",
            None,
            7,
            "sim_tiny",
            vec![MethodKind::Eagl, MethodKind::Eagl],
            vec![0.9, 0.9, 0.7],
            vec![0, 0],
            Default::default(),
        );
        let p = expand(&s);
        assert_eq!(p.runs.len(), 2, "{:?}", p.runs);
        assert_eq!(p.runs[0].budget_frac, 0.9);
        assert_eq!(p.runs[1].budget_frac, 0.7);
    }

    #[test]
    fn fingerprints_are_distinct_per_field() {
        let base = RunKey {
            model: "m".into(),
            method: MethodKind::Eagl,
            budget_frac: 0.7,
            seed: 0,
        };
        let mut others = vec![base.clone(); 4];
        others[0].model = "n".into();
        others[1].method = MethodKind::Alps;
        others[2].budget_frac = 0.7 + 1e-13; // same to 4 decimals, different bits
        others[3].seed = 1;
        for o in &others {
            assert_ne!(o.fingerprint(), base.fingerprint(), "{o:?}");
        }
        // All 16 keys of a small matrix are unique.
        let plan = expand(&spec());
        let mut fps: Vec<u64> = plan.runs.iter().map(RunKey::fingerprint).collect();
        fps.sort_unstable();
        fps.dedup();
        assert_eq!(fps.len(), plan.runs.len());
        assert_eq!(base.hex().len(), 16);
    }
}
