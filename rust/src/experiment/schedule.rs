//! Resumable multi-model scheduler: fan whole experiment runs (checkpoint
//! transform + LSQ fine-tune + eval) out over [`job_pool`] with one
//! backend per worker.
//!
//! Execution has two phases:
//!
//! 1. **Prepare** (sequential over models): train-or-load the base
//!    checkpoint and materialize every gain file a model's pending runs
//!    need.  Gains themselves fan out internally (ALPS probes / HAWQ
//!    draws, PR 2), so the sequential outer loop wastes nothing — and it
//!    guarantees the run phase only ever *reads* the on-disk caches, so
//!    concurrent workers never race on checkpoint or gain files.
//! 2. **Run** (parallel): every pending [`RunKey`] is an independent job.
//!    Each worker lazily opens one coordinator (and thus one backend) per
//!    model it encounters and executes `run_one`.
//!
//! **Determinism.** Records are appended to the registry in *plan order*
//! through a reorder buffer, not in completion order: a worker that
//! finishes run 7 before run 5 parks it until 5 and 6 have flushed.  Every
//! run is bit-deterministic given the (shared, read-only) caches, so the
//! resulting JSONL bytes are identical at any worker count; persisted
//! records carry `wall_s = 0` (wall time is scheduling noise — it is
//! reported on the live progress line instead).  A killed sweep leaves a
//! valid plan-order prefix on disk and resumes by skipping completed keys.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::backend::{self, Backend, BackendKind};
use crate::coordinator::{self, job_pool, Coordinator, RunRecord};

use super::plan::{self, RunKey};
use super::registry::Registry;
use super::spec::ExperimentSpec;

/// How to execute a spec.
pub struct ExecOptions {
    /// Run-level fan-out (and the default gain-estimation fan-out of the
    /// prepare phase).  Results are bit-identical at any value.
    pub workers: usize,
    /// Append to the per-model registry and skip keys already present
    /// (resume).  `false` = ephemeral execution (`mpq run`): nothing is
    /// read from or written to the store.
    pub persist: bool,
    /// Redirect all results (stores, checkpoints, gain caches) under
    /// `<root>/<model>` instead of the canonical per-backend location —
    /// used by tests and hermetic smoke runs.
    pub results_root: Option<PathBuf>,
    /// Print the live per-run progress line.
    pub progress: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            workers: coordinator::default_workers(),
            persist: true,
            results_root: None,
            progress: true,
        }
    }
}

/// What an execution did.
pub struct ExecOutcome {
    /// One record per plan cell, in plan order (resumed + newly run).
    pub records: Vec<RunRecord>,
    /// Newly executed runs.
    pub executed: usize,
    /// Runs skipped because the registry already had their key.
    pub skipped: usize,
    /// Total wall time of the whole execution.
    pub wall_s: f64,
}

/// Resolved per-model execution context.
struct ModelCtx {
    kind: BackendKind,
    results_dir: PathBuf,
}

fn model_ctx(spec: &ExperimentSpec, opts: &ExecOptions, model: &str) -> crate::Result<ModelCtx> {
    let kind = backend::resolve(spec.backend.as_deref(), model)?;
    let results_dir = match &opts.results_root {
        Some(root) => root.join(model),
        None => coordinator::results_dir_for(kind, model),
    };
    Ok(ModelCtx { kind, results_dir })
}

fn open_coordinator(
    spec: &ExperimentSpec,
    ctx: &ModelCtx,
    model: &str,
) -> crate::Result<Coordinator<Box<dyn Backend>>> {
    let mut co =
        Coordinator::open_at(ctx.kind, model, spec.data_seed, ctx.results_dir.clone())?;
    spec.params_for(model).apply(&mut co);
    Ok(co)
}

/// Append completed runs to the registry in pending order (= plan order
/// restricted to not-yet-stored keys), buffering out-of-order
/// completions.  On a fresh sweep pending order *is* plan order, so the
/// JSONL bytes are identical at any worker count; anything still parked
/// when the process dies simply re-runs on resume — the store never
/// holds a gap.
struct Flusher<'a> {
    registry: &'a mut Registry,
    /// Next position in the pending sequence to flush.
    next: usize,
    parked: BTreeMap<usize, RunRecord>,
}

impl Flusher<'_> {
    fn complete(&mut self, pos: usize, mut rec: RunRecord) -> crate::Result<()> {
        // Wall time varies per schedule; the store must not (bit-identity
        // across worker counts).  It lives on the progress line instead.
        rec.wall_s = 0.0;
        self.parked.insert(pos, rec);
        while let Some(rec) = self.parked.remove(&self.next) {
            self.registry.append(&rec)?;
            self.next += 1;
        }
        Ok(())
    }
}

/// Execute a spec end to end.  See the module docs for phase structure,
/// resume, and determinism guarantees.
pub fn execute(spec: &ExperimentSpec, opts: &ExecOptions) -> crate::Result<ExecOutcome> {
    let t0 = Instant::now();
    let the_plan = plan::expand(spec);

    // Per-model contexts (backend kind + results dir), spec order.
    let mut ctxs: BTreeMap<String, ModelCtx> = BTreeMap::new();
    for m in &spec.models {
        ctxs.insert(m.name.clone(), model_ctx(spec, opts, &m.name)?);
    }

    // Registry + resume split.
    let mut registry = if opts.persist {
        let stores = spec
            .models
            .iter()
            .map(|m| {
                let dir = &ctxs[&m.name].results_dir;
                (m.name.clone(), dir.join("sweep.jsonl"))
            })
            .collect();
        Some(Registry::open(stores)?)
    } else {
        None
    };
    let (pending, completed): (Vec<(usize, RunKey)>, Vec<(usize, RunRecord)>) = match &registry {
        Some(reg) => the_plan.split_pending(reg),
        None => (the_plan.runs.iter().cloned().enumerate().collect(), Vec::new()),
    };
    let (n_pending, n_completed) = (pending.len(), completed.len());
    if opts.progress {
        crate::info!(
            "exp \"{}\": {} cells over {} model(s) — {} pending, {} resumed, workers={}",
            spec.name,
            the_plan.runs.len(),
            spec.models.len(),
            n_pending,
            n_completed,
            opts.workers.max(1)
        );
    }

    // Phase 1 — prepare: materialize base checkpoints + gain files for
    // every model that still has pending work, so the run phase is
    // read-only outside the registry.
    for m in &spec.models {
        let my_pending: Vec<&RunKey> = pending
            .iter()
            .map(|(_, k)| k)
            .filter(|k| k.model == m.name)
            .collect();
        if my_pending.is_empty() {
            continue;
        }
        let mut co = open_coordinator(spec, &ctxs[&m.name], &m.name)?;
        // Gain estimation fans out internally; default its width to the
        // scheduler's unless the manifest pinned one for this model.
        if spec.params_for(&m.name).workers.is_none() {
            co.workers = opts.workers.max(1);
        }
        co.base_checkpoint()?;
        let mut kinds: Vec<_> = my_pending
            .iter()
            .map(|k| k.method)
            .filter(|k| k.is_gain_based())
            .collect();
        kinds.sort_by_key(|k| k.name());
        kinds.dedup();
        for kind in kinds {
            co.gains(kind)?;
        }
    }

    // Phase 2 — run: fan pending cells over the pool; flush in pending
    // order.  Items carry (pos in pending sequence, plan idx, key).
    let flusher = registry.as_mut().map(|reg| {
        Mutex::new(Flusher {
            registry: reg,
            next: 0,
            parked: BTreeMap::new(),
        })
    });
    let done = AtomicUsize::new(0);
    let items: Vec<(usize, usize, RunKey)> = pending
        .iter()
        .enumerate()
        .map(|(pos, (idx, key))| (pos, *idx, key.clone()))
        .collect();
    let new_records: Vec<(usize, RunRecord)> = if items.is_empty() {
        Vec::new()
    } else {
        job_pool(
            items,
            opts.workers.max(1),
            || Ok(BTreeMap::<String, Coordinator<Box<dyn Backend>>>::new()),
            |cos, (pos, idx, key): (usize, usize, RunKey)| {
                if !cos.contains_key(&key.model) {
                    let mut co = open_coordinator(spec, &ctxs[&key.model], &key.model)?;
                    co.workers = 1; // gains are cached; runs are the unit of parallelism
                    cos.insert(key.model.clone(), co);
                }
                let co = cos.get_mut(&key.model).unwrap();
                let rec = co.run_one(key.method, key.budget_frac, key.seed)?;
                if opts.progress {
                    let n = done.fetch_add(1, Ordering::SeqCst) + 1;
                    crate::info!(
                        "[{n}/{n_pending}] {}  metric {:.4}  loss {:.4}  {:.1}s",
                        key.label(),
                        rec.metric,
                        rec.loss,
                        rec.wall_s
                    );
                }
                if let Some(fl) = &flusher {
                    fl.lock().unwrap().complete(pos, rec.clone())?;
                }
                Ok((idx, rec))
            },
        )?
    };
    drop(flusher);

    // Merge resumed + new back into plan order.
    let mut by_idx: BTreeMap<usize, RunRecord> = completed.into_iter().collect();
    by_idx.extend(new_records);
    crate::ensure!(
        by_idx.len() == the_plan.runs.len(),
        "scheduler lost runs: {} of {}",
        by_idx.len(),
        the_plan.runs.len()
    );
    Ok(ExecOutcome {
        records: by_idx.into_values().collect(),
        executed: n_pending,
        skipped: n_completed,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}
