//! Statistics substrate: the tests and fits the paper's evaluation uses.
//!
//!  * Wilcoxon rank-sum (Mann–Whitney U) — the paper's significance test
//!    for frontier comparisons ("p = 0.0079, N = 5"): exact for small
//!    samples, normal approximation with tie correction otherwise.
//!  * Ordinary least squares — Appendix A's linearity experiment and the
//!    Appendix B regression-coefficient oracle.
//!  * Pearson correlation, mean/std aggregation.

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx).powi(2);
        syy += (y - my).powi(2);
    }
    sxy / (sxx.sqrt() * syy.sqrt()).max(1e-300)
}

// ---------------------------------------------------------------------------
// Wilcoxon rank-sum / Mann-Whitney U
// ---------------------------------------------------------------------------

/// Two-sided Wilcoxon rank-sum test. Returns (U statistic of sample a,
/// two-sided p-value).  Exact null distribution for n+m <= 20 (the paper's
/// N=5 per group falls here — p=0.0079 is the exact two-sided minimum for
/// 5v5), normal approximation with tie correction otherwise.
pub fn ranksum(a: &[f64], b: &[f64]) -> (f64, f64) {
    let n = a.len();
    let m = b.len();
    assert!(n > 0 && m > 0);
    // Midranks over the pooled sample.
    let mut pooled: Vec<(f64, usize)> = a
        .iter()
        .map(|&x| (x, 0usize))
        .chain(b.iter().map(|&x| (x, 1usize)))
        .collect();
    pooled.sort_by(|p, q| p.0.partial_cmp(&q.0).unwrap());
    let mut ranks = vec![0.0f64; pooled.len()];
    let mut i = 0;
    while i < pooled.len() {
        let mut j = i;
        while j + 1 < pooled.len() && pooled[j + 1].0 == pooled[i].0 {
            j += 1;
        }
        let r = (i + j) as f64 / 2.0 + 1.0;
        for slot in ranks.iter_mut().take(j + 1).skip(i) {
            *slot = r;
        }
        i = j + 1;
    }
    let ra: f64 = pooled
        .iter()
        .zip(&ranks)
        .filter(|((_, g), _)| *g == 0)
        .map(|(_, &r)| r)
        .sum();
    let u = ra - (n * (n + 1)) as f64 / 2.0;

    let ties = {
        let mut t = 0.0;
        let mut i = 0;
        while i < pooled.len() {
            let mut j = i;
            while j + 1 < pooled.len() && pooled[j + 1].0 == pooled[i].0 {
                j += 1;
            }
            let c = (j - i + 1) as f64;
            t += c * c * c - c;
            i = j + 1;
        }
        t
    };

    let p = if n + m <= 20 && ties == 0.0 {
        exact_ranksum_p(u, n, m)
    } else {
        // Normal approximation with tie correction.
        let nm = (n * m) as f64;
        let nn = (n + m) as f64;
        let mu = nm / 2.0;
        let sigma2 = nm / 12.0 * (nn + 1.0 - ties / (nn * (nn - 1.0)));
        if sigma2 <= 0.0 {
            return (u, 1.0);
        }
        let z = (u - mu).abs() - 0.5; // continuity correction
        let z = z.max(0.0) / sigma2.sqrt();
        2.0 * (1.0 - normal_cdf(z))
    };
    (u, p.min(1.0))
}

/// Exact two-sided p-value for the Mann-Whitney U statistic: enumerate the
/// number of subsets of ranks (no ties) achieving each U via the standard
/// counting DP.
fn exact_ranksum_p(u: f64, n: usize, m: usize) -> f64 {
    let max_u = n * m;
    // count[k][u]: number of ways to choose k of the first t ranks with
    // rank-sum offset u; iterate t implicitly.
    let mut count = vec![vec![0f64; max_u + 1]; n + 1];
    count[0][0] = 1.0;
    for t in 1..=(n + m) {
        // Adding rank t: each element chosen from positions <= t.
        for k in (1..=n.min(t)).rev() {
            for uu in (0..=max_u).rev() {
                let contrib = t - k; // U contribution of picking rank t as k-th
                if contrib <= uu && contrib <= m {
                    count[k][uu] += count[k - 1][uu - contrib];
                }
            }
        }
    }
    let total: f64 = count[n].iter().sum();
    let u_round = u.round() as usize;
    let mu = max_u as f64 / 2.0;
    // Two-sided: sum probabilities of outcomes at least as extreme.
    let dist = (u - mu).abs();
    let mut p = 0.0;
    for (uu, &c) in count[n].iter().enumerate() {
        if ((uu as f64) - mu).abs() >= dist - 1e-9 {
            p += c;
        }
    }
    let _ = u_round;
    p / total
}

/// Standard normal CDF via the Abramowitz–Stegun erf approximation.
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    // A&S 7.1.26, |error| <= 1.5e-7.
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

// ---------------------------------------------------------------------------
// Ordinary least squares
// ---------------------------------------------------------------------------

/// OLS fit y ≈ X·beta (+ intercept appended as the last coefficient).
/// Solves the normal equations by Gaussian elimination with partial
/// pivoting and ridge jitter for rank-deficient designs.
pub struct Ols {
    /// Coefficients; `beta[n_features]` is the intercept.
    pub beta: Vec<f64>,
}

impl Ols {
    pub fn fit(xs: &[Vec<f64>], ys: &[f64]) -> crate::Result<Ols> {
        let n = xs.len();
        crate::ensure!(n == ys.len() && n > 0, "bad OLS inputs");
        let d = xs[0].len() + 1; // + intercept
        // Normal equations A = X'X (d×d), b = X'y.
        let mut a = vec![0.0f64; d * d];
        let mut b = vec![0.0f64; d];
        for (row, &y) in xs.iter().zip(ys) {
            let mut ext: Vec<f64> = row.clone();
            ext.push(1.0);
            for i in 0..d {
                b[i] += ext[i] * y;
                for j in 0..d {
                    a[i * d + j] += ext[i] * ext[j];
                }
            }
        }
        // Ridge jitter for numerical rank safety.
        for i in 0..d {
            a[i * d + i] += 1e-9;
        }
        let beta = solve_linear(&mut a, &mut b, d)?;
        Ok(Ols { beta })
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        let d = self.beta.len() - 1;
        assert_eq!(x.len(), d);
        x.iter().zip(&self.beta[..d]).map(|(a, b)| a * b).sum::<f64>() + self.beta[d]
    }

    /// Per-feature coefficients (excluding intercept) — the Appendix-B
    /// oracle gains.
    pub fn coefficients(&self) -> &[f64] {
        &self.beta[..self.beta.len() - 1]
    }
}

/// Gaussian elimination with partial pivoting; solves A x = b in place.
fn solve_linear(a: &mut [f64], b: &mut [f64], d: usize) -> crate::Result<Vec<f64>> {
    for col in 0..d {
        // Pivot.
        let mut piv = col;
        for r in col + 1..d {
            if a[r * d + col].abs() > a[piv * d + col].abs() {
                piv = r;
            }
        }
        crate::ensure!(a[piv * d + col].abs() > 1e-12, "singular system");
        if piv != col {
            for j in 0..d {
                a.swap(col * d + j, piv * d + j);
            }
            b.swap(col, piv);
        }
        // Eliminate.
        for r in col + 1..d {
            let f = a[r * d + col] / a[col * d + col];
            if f == 0.0 {
                continue;
            }
            for j in col..d {
                a[r * d + j] -= f * a[col * d + j];
            }
            b[r] -= f * b[col];
        }
    }
    // Back substitution.
    let mut x = vec![0.0; d];
    for col in (0..d).rev() {
        let mut s = b[col];
        for j in col + 1..d {
            s -= a[col * d + j] * x[j];
        }
        x[col] = s / a[col * d + col];
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranksum_extreme_5v5_gives_paper_p() {
        // Completely separated 5 vs 5 → the paper's p = 0.0079 (two-sided
        // exact: 2/C(10,5) = 2/252).
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [6.0, 7.0, 8.0, 9.0, 10.0];
        let (_, p) = ranksum(&a, &b);
        assert!((p - 2.0 / 252.0).abs() < 1e-9, "p = {p}");
    }

    #[test]
    fn ranksum_identical_groups_p_one() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0];
        let b = a;
        let (_, p) = ranksum(&a, &b);
        assert!(p > 0.9, "p = {p}");
    }

    #[test]
    fn ranksum_3v3_exact() {
        // Fully separated 3v3: p = 2/C(6,3) = 0.1.
        let (_, p) = ranksum(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        assert!((p - 0.1).abs() < 1e-9, "p = {p}");
    }

    #[test]
    fn normal_cdf_sane() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!(normal_cdf(-5.0) < 1e-5);
    }

    #[test]
    fn ols_recovers_plane() {
        // y = 2a - 3b + 0.5
        let xs: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![(i % 7) as f64, (i % 5) as f64])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|r| 2.0 * r[0] - 3.0 * r[1] + 0.5).collect();
        let fit = Ols::fit(&xs, &ys).unwrap();
        assert!((fit.beta[0] - 2.0).abs() < 1e-6);
        assert!((fit.beta[1] + 3.0).abs() < 1e-6);
        assert!((fit.beta[2] - 0.5).abs() < 1e-6);
        assert!((fit.predict(&[3.0, 2.0]) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn ols_handles_noise() {
        let mut rng = crate::rng::Pcg32::new(3, 3);
        let xs: Vec<Vec<f64>> = (0..200)
            .map(|_| vec![rng.uniform() as f64, rng.uniform() as f64, rng.uniform() as f64])
            .collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|r| 1.0 * r[0] + 2.0 * r[1] - 1.5 * r[2] + 0.01 * rng.normal() as f64)
            .collect();
        let fit = Ols::fit(&xs, &ys).unwrap();
        assert!((fit.beta[0] - 1.0).abs() < 0.05);
        assert!((fit.beta[1] - 2.0).abs() < 0.05);
        assert!((fit.beta[2] + 1.5).abs() < 0.05);
    }
}
