//! Property-testing substrate (offline environment — no proptest).
//!
//! A deliberately small harness: run a property over many seeded random
//! cases; on failure, retry with progressively "smaller" generator budgets
//! to report a reduced counterexample seed.  Generators are plain closures
//! over [`Pcg32`], so strategies compose as ordinary Rust.

use crate::rng::Pcg32;

/// Controls for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0x9E3779B9 }
    }
}

/// Run `prop` over `cfg.cases` random cases.  `gen` draws a case from the
/// RNG; `prop` returns Err(description) on violation.  Panics with the
/// failing seed + case number so the run is reproducible.
pub fn forall<T: std::fmt::Debug>(
    cfg: &Config,
    mut gen: impl FnMut(&mut Pcg32) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Pcg32::new(cfg.seed.wrapping_add(case as u64), 0xFACE);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {}):\n  input: {input:?}\n  {msg}",
                cfg.seed.wrapping_add(case as u64)
            );
        }
    }
}

/// Convenience: assert two f64 are within tolerance, with context.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(
            &Config { cases: 64, ..Config::default() },
            |rng| (rng.uniform(), rng.uniform()),
            |(a, b)| {
                if a + b >= *a {
                    Ok(())
                } else {
                    Err("monotone add failed".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_violations() {
        forall(
            &Config { cases: 64, ..Config::default() },
            |rng| rng.below(10),
            |x| if *x < 9 { Ok(()) } else { Err("hit 9".into()) },
        );
    }

    #[test]
    fn close_tolerates_scale() {
        assert!(close(1e9, 1e9 + 1.0, 1e-6, "big").is_ok());
        assert!(close(1.0, 2.0, 1e-6, "small").is_err());
    }
}
