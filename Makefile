# Tier-1 verification and common developer entry points.
#
# `make verify` is hermetic: the default cargo build has zero external
# dependencies and the test suite runs entirely on the pure-Rust sim
# backend — no `artifacts/` directory needed.  Artifact-dependent tests
# are compiled only with `--features pjrt` (which needs the vendored xla
# crate, see rust/Cargo.toml) and skip themselves at runtime when
# artifacts are absent.

.PHONY: verify test build bench bench-quick lint sanitize-smoke simd-matrix packed-smoke exp-smoke serve-smoke http-smoke degrade-smoke trace-smoke verify-pjrt artifacts clean

# Tier-1: must pass in a clean checkout.  lint, sanitize-smoke,
# simd-matrix, bench-quick, packed-smoke, exp-smoke, serve-smoke,
# http-smoke, degrade-smoke and trace-smoke ride along as smoke steps so
# the invariant linter (self-hosted over rust/src), the Miri pass over
# the concurrency-critical unit tests, the simd-feature build, the
# bench binary (and its BENCH_hotpath.json emission), the packed-kernel
# CLI path, the manifest-driven experiment path, the serving engine
# (in-process and over real loopback sockets), the SLO-driven
# degradation loop, and the span-tracing/stage-profiler observability
# path can never silently rot.
verify:
	cargo build --release && cargo test -q && $(MAKE) lint && $(MAKE) sanitize-smoke && $(MAKE) simd-matrix && $(MAKE) bench-quick && $(MAKE) packed-smoke && $(MAKE) exp-smoke && $(MAKE) serve-smoke && $(MAKE) http-smoke && $(MAKE) degrade-smoke && $(MAKE) trace-smoke

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Quick-mode hot-path bench; writes the machine-readable perf record
# BENCH_hotpath.json at the repo root (see rust/README.md §Performance).
# Re-running prints speedups against the recorded file.  The target
# fails loudly if the record still has no measurements after the run —
# a seed-shaped `measurements: []` file passing silently would let the
# whole perf trajectory rot.
bench-quick:
	MPQ_BENCH_QUICK=1 MPQ_BENCH_OUT=$(CURDIR)/BENCH_hotpath.json cargo bench --bench perf_hotpath
	@grep -q '"name"' $(CURDIR)/BENCH_hotpath.json || { \
	  echo "bench-quick: BENCH_hotpath.json recorded no measurements"; exit 1; }

# Zero-dependency invariant linter over rust/src (see rust/README.md
# §Static analysis).  The first run is the gate: `mpq lint` exits 0
# clean / 1 findings / 2 config error (stale or malformed waivers in
# rust/lint-waivers.json fail closed), and no pipe sits between cargo
# and the shell so that exit status stays load-bearing.  The second run
# pins the machine-readable report at LINT_report.json; the grep guard
# mirrors bench-quick's — an accidentally emptied rule table must never
# read as "everything passes".
lint:
	cargo run --release -q -p mpq -- lint
	cargo run --release -q -p mpq -- lint --json > $(CURDIR)/LINT_report.json
	@grep -q '"rules":\["' $(CURDIR)/LINT_report.json || { \
	  echo "lint: LINT_report.json records an empty rule set"; exit 1; }
	@echo "lint OK (report at LINT_report.json)"

# Miri pass over the concurrency-critical unit tests (span-trace
# histograms/rings, metrics counters, batcher state machine).  Miri
# ships only on nightly; when the toolchain or component is missing the
# target skips LOUDLY — the gap shows up in every verify log instead of
# silently passing.  -Zmiri-disable-isolation lets the trace tests read
# the host clock (Instant::now) under the interpreter.
sanitize-smoke:
	@if rustup toolchain list 2>/dev/null | grep -q '^nightly' && \
	  rustup component list --toolchain nightly 2>/dev/null | grep -q 'miri.*(installed)'; then \
	  MIRIFLAGS="-Zmiri-disable-isolation" cargo +nightly miri test -q -p mpq --lib -- \
	    serve::trace:: serve::metrics:: serve::batcher:: && \
	  echo "sanitize-smoke OK (miri over trace/metrics/batcher unit tests)"; \
	else \
	  echo "sanitize-smoke SKIPPED: nightly toolchain with miri not installed"; \
	  echo "  (install: rustup toolchain install nightly && rustup component add miri --toolchain nightly)"; \
	fi

# The packed-kernel contracts must hold in both builds: the default
# (scalar|unrolled tiles) and the 16-wide `--features simd` build.  The
# simd variant is selected at runtime but its tiles only exist behind
# the feature gate, so the bit-identity property tests and the serve
# integration tests run once per build.
simd-matrix:
	cargo test -q -p mpq --features simd --lib packed
	cargo test -q -p mpq --features simd --test packed_kernels

# End-to-end smoke of the manifest-driven experiment scheduler: run a
# tiny two-model manifest on the hermetic sim backend into a scratch
# results root, assert the registry row count, and re-invoke to assert
# resume adds nothing (see rust/README.md §Experiments).
EXP_SMOKE_DIR := $(CURDIR)/.exp-smoke-results
exp-smoke:
	rm -rf $(EXP_SMOKE_DIR)
	MPQ_RESULTS=$(EXP_SMOKE_DIR) cargo run --release -q -p mpq -- exp --manifest rust/examples/manifests/smoke.json --workers 2
	@rows=$$(cat $(EXP_SMOKE_DIR)/sim_tiny/sweep.jsonl $(EXP_SMOKE_DIR)/sim_skew/sweep.jsonl | wc -l); \
	test "$$rows" -eq 8 || { echo "exp-smoke: expected 8 registry rows, got $$rows"; exit 1; }
	MPQ_RESULTS=$(EXP_SMOKE_DIR) cargo run --release -q -p mpq -- exp --manifest rust/examples/manifests/smoke.json --workers 2
	@rows=$$(cat $(EXP_SMOKE_DIR)/sim_tiny/sweep.jsonl $(EXP_SMOKE_DIR)/sim_skew/sweep.jsonl | wc -l); \
	test "$$rows" -eq 8 || { echo "exp-smoke resume: expected 8 rows, got $$rows"; exit 1; }; \
	echo "exp-smoke OK (8 rows, resume added none)"
	rm -rf $(EXP_SMOKE_DIR)

# CLI smoke of the packed-kernel path: one-shot `mpq infer` with the
# reference kernels, then with `--kernel packed` across every tile
# variant — default (unrolled), scalar, unrolled with row-parallel
# `--gemm-threads 2`, and the `--features simd` build's simd tiles —
# over a shared scratch results root (base checkpoint trained once,
# reused by all runs).  Packed evaluation is bit-identical by
# construction in every cell, so the printed loss/accuracy lines must
# match byte for byte (timing stripped).
PACKED_SMOKE_DIR := $(CURDIR)/.packed-smoke-results
# (No pipes around cargo: a pipeline would mask the binary's exit status
# and let a broken infer path still "pass" — redirect, then post-process.)
packed-smoke:
	rm -rf $(PACKED_SMOKE_DIR)
	@mkdir -p $(PACKED_SMOKE_DIR)
	MPQ_RESULTS=$(PACKED_SMOKE_DIR) cargo run --release -q -p mpq -- infer \
	  --model sim_tiny --backend sim --base-steps 60 --budget 0.7 --method eagl \
	  --samples 32 --kernel reference > $(PACKED_SMOKE_DIR)/reference.raw
	MPQ_RESULTS=$(PACKED_SMOKE_DIR) cargo run --release -q -p mpq -- infer \
	  --model sim_tiny --backend sim --base-steps 60 --budget 0.7 --method eagl \
	  --samples 32 --kernel packed > $(PACKED_SMOKE_DIR)/packed.raw
	MPQ_RESULTS=$(PACKED_SMOKE_DIR) cargo run --release -q -p mpq -- infer \
	  --model sim_tiny --backend sim --base-steps 60 --budget 0.7 --method eagl \
	  --samples 32 --kernel packed --packed-variant scalar \
	  > $(PACKED_SMOKE_DIR)/scalar.raw
	MPQ_RESULTS=$(PACKED_SMOKE_DIR) cargo run --release -q -p mpq -- infer \
	  --model sim_tiny --backend sim --base-steps 60 --budget 0.7 --method eagl \
	  --samples 32 --kernel packed --packed-variant unrolled --gemm-threads 2 \
	  > $(PACKED_SMOKE_DIR)/threads.raw
	MPQ_RESULTS=$(PACKED_SMOKE_DIR) cargo run --release -q -p mpq --features simd -- infer \
	  --model sim_tiny --backend sim --base-steps 60 --budget 0.7 --method eagl \
	  --samples 32 --kernel packed --packed-variant simd \
	  > $(PACKED_SMOKE_DIR)/simd.raw
	@for v in reference packed scalar threads simd; do \
	  sed 's/, [0-9.]* ms$$//' $(PACKED_SMOKE_DIR)/$$v.raw > $(PACKED_SMOKE_DIR)/$$v.out; \
	done
	@test -s $(PACKED_SMOKE_DIR)/reference.out || { echo "packed-smoke: empty infer output"; exit 1; }
	@for v in packed scalar threads simd; do \
	  cmp -s $(PACKED_SMOKE_DIR)/reference.out $(PACKED_SMOKE_DIR)/$$v.out || { \
	    echo "packed-smoke: $$v infer output differs from reference:"; \
	    diff $(PACKED_SMOKE_DIR)/reference.out $(PACKED_SMOKE_DIR)/$$v.out; exit 1; }; \
	done
	@echo "packed-smoke OK (scalar/unrolled/simd x gemm-threads eval bit-identical to reference)"
	rm -rf $(PACKED_SMOKE_DIR)

# End-to-end smoke of the serving engine: loadgen drives `mpq serve` on
# the hermetic sim backend (EAGL selection at a 70% budget over a fresh
# scratch results root), once per kernel path.  The binary itself asserts
# the serving invariants — every request completed with zero failures
# (which implies nonzero throughput), monotone/contiguous response ids,
# clean drain — and exits nonzero on any violation (see rust/README.md
# §Serving); the target then compares the two runs' summary accuracy,
# which the packed path's epsilon contract must leave unchanged.
# (Redirect instead of `| tee`: a pipeline would mask the binary's exit
# status, so its post-run invariant failures could no longer fail the gate.)
SERVE_SMOKE_DIR := $(CURDIR)/.serve-smoke-results
serve-smoke:
	rm -rf $(SERVE_SMOKE_DIR)
	@mkdir -p $(SERVE_SMOKE_DIR)
	MPQ_RESULTS=$(SERVE_SMOKE_DIR) cargo run --release -q -p mpq -- serve \
	  --model sim_tiny --backend sim --base-steps 60 --budget 0.7 --method eagl \
	  --requests 48 --max-request 4 --workers 2 --max-batch 8 --batch-timeout-ms 2 \
	  --kernel reference > $(SERVE_SMOKE_DIR)/reference.out
	@cat $(SERVE_SMOKE_DIR)/reference.out
	MPQ_RESULTS=$(SERVE_SMOKE_DIR) cargo run --release -q -p mpq -- serve \
	  --model sim_tiny --backend sim --base-steps 60 --budget 0.7 --method eagl \
	  --requests 48 --max-request 4 --workers 2 --max-batch 8 --batch-timeout-ms 2 \
	  --kernel packed > $(SERVE_SMOKE_DIR)/packed.out
	@cat $(SERVE_SMOKE_DIR)/packed.out
	@ref=$$(grep -o 'accuracy *[0-9.]*' $(SERVE_SMOKE_DIR)/reference.out | head -1); \
	pk=$$(grep -o 'accuracy *[0-9.]*' $(SERVE_SMOKE_DIR)/packed.out | head -1); \
	test -n "$$ref" && test "$$ref" = "$$pk" || { \
	  echo "serve-smoke: kernel accuracy mismatch: reference [$$ref] vs packed [$$pk]"; exit 1; }; \
	echo "serve-smoke OK (packed == reference $$pk)"
	rm -rf $(SERVE_SMOKE_DIR)

# End-to-end smoke of the HTTP front door: `mpq serve --listen` binds a
# real loopback socket (port 0 picks a free port), self-drives it with
# the open-loop loadgen over TCP, scrapes `/metrics` once, and asserts
# the serving invariants in-binary (every request answered exactly once,
# admitted == answered, clean drain) — the target gates on the binary's
# exit status plus its "metrics scrape OK" and "http-serve OK" lines.
# (Redirect instead of a pipe so the exit status stays load-bearing.)
HTTP_SMOKE_DIR := $(CURDIR)/.http-smoke-results
http-smoke:
	rm -rf $(HTTP_SMOKE_DIR)
	@mkdir -p $(HTTP_SMOKE_DIR)
	MPQ_RESULTS=$(HTTP_SMOKE_DIR) cargo run --release -q -p mpq -- serve \
	  --model sim_tiny --backend sim --base-steps 60 --budget 0.7 --method eagl \
	  --listen 127.0.0.1:0 --requests 48 --max-request 4 --mode open --rate 400 \
	  --workers 2 --max-batch 8 --batch-timeout-ms 2 > $(HTTP_SMOKE_DIR)/http.out
	@cat $(HTTP_SMOKE_DIR)/http.out
	@grep -q 'metrics scrape OK' $(HTTP_SMOKE_DIR)/http.out || { \
	  echo "http-smoke: missing /metrics scrape"; exit 1; }
	@grep -q 'http-serve OK' $(HTTP_SMOKE_DIR)/http.out || { \
	  echo "http-smoke: missing http-serve OK line"; exit 1; }
	@echo "http-smoke OK (socket loadgen + /metrics scrape)"
	rm -rf $(HTTP_SMOKE_DIR)

# End-to-end smoke of graceful degradation: sweep two budgets on the
# hermetic sim backend so the registry records a real two-point
# accuracy-cost frontier, then serve it with the sim-time spike drill
# (`--degrade spike`) and a loopback front door whose /metrics the
# binary scrapes before and after the drill.  The binary asserts >=1
# downgrade + >=1 recovery, zero dropped requests, a monotone
# mpq_ctl_swap_total, and the active-budget gauge matching the final
# frontier level, exiting nonzero on any violation; the target gates on
# its "degrade OK" and "ctl metrics OK" lines.  (Redirect instead of a
# pipe so the exit status stays load-bearing.)
DEGRADE_SMOKE_DIR := $(CURDIR)/.degrade-smoke-results
degrade-smoke:
	rm -rf $(DEGRADE_SMOKE_DIR)
	@mkdir -p $(DEGRADE_SMOKE_DIR)
	MPQ_RESULTS=$(DEGRADE_SMOKE_DIR) cargo run --release -q -p mpq -- sweep \
	  --model sim_tiny --backend sim --base-steps 60 --methods eagl \
	  --budgets 0.95,0.6 --seeds 1
	MPQ_RESULTS=$(DEGRADE_SMOKE_DIR) cargo run --release -q -p mpq -- serve \
	  --model sim_tiny --backend sim --base-steps 60 \
	  --frontier-from $(DEGRADE_SMOKE_DIR)/sim_tiny/sweep.jsonl \
	  --degrade spike --workers 2 --max-batch 8 --batch-timeout-ms 2 \
	  --listen 127.0.0.1:0 > $(DEGRADE_SMOKE_DIR)/degrade.out
	@cat $(DEGRADE_SMOKE_DIR)/degrade.out
	@grep -q 'ctl metrics OK' $(DEGRADE_SMOKE_DIR)/degrade.out || { \
	  echo "degrade-smoke: missing ctl metrics OK line"; exit 1; }
	@grep -q 'degrade OK' $(DEGRADE_SMOKE_DIR)/degrade.out || { \
	  echo "degrade-smoke: missing degrade OK line"; exit 1; }
	@echo "degrade-smoke OK (spike -> degrade -> recover, ctl gauges consistent)"
	rm -rf $(DEGRADE_SMOKE_DIR)

# End-to-end smoke of the observability path: a traced `--listen` run
# (sample 1-in-1) that must print the pinned stage-metrics gate and
# write a Chrome trace + per-request latency JSONL; the trace file is
# then re-validated by `mpq trace` (complete per-request span sets,
# monotone timestamps, all lifecycle stages covered).  Finally the
# degrade drill runs twice at different worker counts with
# `--decision-log`: the controller's JSONL decision log must be
# byte-identical — it derives only from the sim queue model, never from
# scheduling.  (Redirect instead of a pipe so the binary's exit status
# stays load-bearing.)
TRACE_SMOKE_DIR := $(CURDIR)/.trace-smoke-results
trace-smoke:
	rm -rf $(TRACE_SMOKE_DIR)
	@mkdir -p $(TRACE_SMOKE_DIR)
	MPQ_RESULTS=$(TRACE_SMOKE_DIR) cargo run --release -q -p mpq -- serve \
	  --model sim_tiny --backend sim --base-steps 60 --budget 0.7 --method eagl \
	  --listen 127.0.0.1:0 --requests 32 --max-request 4 --workers 2 --max-batch 8 \
	  --batch-timeout-ms 2 --trace-sample 1 \
	  --trace-out $(TRACE_SMOKE_DIR)/trace.json \
	  --latency-out $(TRACE_SMOKE_DIR)/latency.jsonl > $(TRACE_SMOKE_DIR)/serve.out
	@cat $(TRACE_SMOKE_DIR)/serve.out
	@grep -q 'stage metrics OK' $(TRACE_SMOKE_DIR)/serve.out || { \
	  echo "trace-smoke: missing stage metrics OK line"; exit 1; }
	@grep -q 'trace written to' $(TRACE_SMOKE_DIR)/serve.out || { \
	  echo "trace-smoke: missing trace written line"; exit 1; }
	@lines=$$(wc -l < $(TRACE_SMOKE_DIR)/latency.jsonl); \
	test "$$lines" -eq 32 || { \
	  echo "trace-smoke: expected 32 latency lines, got $$lines"; exit 1; }
	MPQ_RESULTS=$(TRACE_SMOKE_DIR) cargo run --release -q -p mpq -- trace \
	  --file $(TRACE_SMOKE_DIR)/trace.json > $(TRACE_SMOKE_DIR)/check.out
	@cat $(TRACE_SMOKE_DIR)/check.out
	@grep -q 'trace OK' $(TRACE_SMOKE_DIR)/check.out || { \
	  echo "trace-smoke: trace file failed validation"; exit 1; }
	MPQ_RESULTS=$(TRACE_SMOKE_DIR) cargo run --release -q -p mpq -- sweep \
	  --model sim_tiny --backend sim --base-steps 60 --methods eagl \
	  --budgets 0.95,0.6 --seeds 1
	MPQ_RESULTS=$(TRACE_SMOKE_DIR) cargo run --release -q -p mpq -- serve \
	  --model sim_tiny --backend sim --base-steps 60 \
	  --frontier-from $(TRACE_SMOKE_DIR)/sim_tiny/sweep.jsonl \
	  --degrade spike --workers 2 --max-batch 8 --batch-timeout-ms 2 \
	  --decision-log $(TRACE_SMOKE_DIR)/decisions-a.jsonl \
	  > $(TRACE_SMOKE_DIR)/degrade-a.out
	MPQ_RESULTS=$(TRACE_SMOKE_DIR) cargo run --release -q -p mpq -- serve \
	  --model sim_tiny --backend sim --base-steps 60 \
	  --frontier-from $(TRACE_SMOKE_DIR)/sim_tiny/sweep.jsonl \
	  --degrade spike --workers 4 --max-batch 8 --batch-timeout-ms 2 \
	  --decision-log $(TRACE_SMOKE_DIR)/decisions-b.jsonl \
	  > $(TRACE_SMOKE_DIR)/degrade-b.out
	@cmp $(TRACE_SMOKE_DIR)/decisions-a.jsonl $(TRACE_SMOKE_DIR)/decisions-b.jsonl || { \
	  echo "trace-smoke: --decision-log diverged across worker counts"; exit 1; }
	@echo "trace-smoke OK (all stages validated, stage metrics pinned, decision log deterministic)"
	rm -rf $(TRACE_SMOKE_DIR)

# Full verification including the PJRT/AOT path (requires the vendored
# `xla` dependency to be uncommented in rust/Cargo.toml and, for the
# tests to run rather than skip, `make artifacts`).
verify-pjrt:
	cargo build --release --features pjrt && cargo test -q --features pjrt

# Build the AOT artifacts through the Python/JAX/Pallas path (offline
# environments without jax can't run this — use the sim backend instead).
artifacts:
	python3 -m python.compile.aot --out artifacts

clean:
	cargo clean
	rm -rf results $(EXP_SMOKE_DIR) $(SERVE_SMOKE_DIR) $(PACKED_SMOKE_DIR) $(HTTP_SMOKE_DIR) $(DEGRADE_SMOKE_DIR) $(TRACE_SMOKE_DIR)
	rm -f LINT_report.json
