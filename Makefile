# Tier-1 verification and common developer entry points.
#
# `make verify` is hermetic: the default cargo build has zero external
# dependencies and the test suite runs entirely on the pure-Rust sim
# backend — no `artifacts/` directory needed.  Artifact-dependent tests
# are compiled only with `--features pjrt` (which needs the vendored xla
# crate, see rust/Cargo.toml) and skip themselves at runtime when
# artifacts are absent.

.PHONY: verify test build bench bench-quick verify-pjrt artifacts clean

# Tier-1: must pass in a clean checkout.  bench-quick rides along as a
# smoke step so the bench binary (and its BENCH_hotpath.json emission)
# can never silently rot.
verify:
	cargo build --release && cargo test -q && $(MAKE) bench-quick

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Quick-mode hot-path bench; writes the machine-readable perf record
# BENCH_hotpath.json at the repo root (see rust/README.md §Performance).
# Re-running prints speedups against the recorded file.
bench-quick:
	MPQ_BENCH_QUICK=1 MPQ_BENCH_OUT=$(CURDIR)/BENCH_hotpath.json cargo bench --bench perf_hotpath

# Full verification including the PJRT/AOT path (requires the vendored
# `xla` dependency to be uncommented in rust/Cargo.toml and, for the
# tests to run rather than skip, `make artifacts`).
verify-pjrt:
	cargo build --release --features pjrt && cargo test -q --features pjrt

# Build the AOT artifacts through the Python/JAX/Pallas path (offline
# environments without jax can't run this — use the sim backend instead).
artifacts:
	python3 -m python.compile.aot --out artifacts

clean:
	cargo clean
	rm -rf results
