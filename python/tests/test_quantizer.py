"""LSQ quantizer semantics: forward grid, STE, step-size gradient."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.quantizer import init_step_size, lsq, qrange, quantize_weight, weight_codes

SETTINGS = dict(max_examples=30, deadline=None)


def test_qrange_values():
    assert qrange(4.0, signed=True) == (-8.0, 7.0)
    assert qrange(2.0, signed=True) == (-2.0, 1.0)
    qn, qp = qrange(4.0, signed=False)
    assert (qn, qp) == (0.0, 15.0)


def test_qrange_traced_bits():
    """Bit-widths arrive as runtime tensors; qrange must trace."""
    f = jax.jit(lambda b: qrange(b, signed=True)[1])
    assert float(f(jnp.asarray(4.0))) == 7.0
    assert float(f(jnp.asarray(2.0))) == 1.0


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 10_000),
    s=st.floats(0.01, 1.0),
    bits=st.sampled_from([2, 4, 8]),
)
def test_forward_on_grid(seed, s, bits):
    v = jax.random.normal(jax.random.PRNGKey(seed), (256,))
    qn, qp = qrange(float(bits), signed=True)
    out = np.asarray(lsq(v, s, qn, qp))
    codes = out / s
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    assert codes.min() >= qn - 1e-4 and codes.max() <= qp + 1e-4


def test_ste_gradient_masks_out_of_range():
    v = jnp.asarray([0.05, 10.0, -10.0, -0.3])
    g = jax.grad(lambda v: jnp.sum(lsq(v, 0.1, -8.0, 7.0)))(v)
    np.testing.assert_allclose(np.asarray(g), [1.0, 0.0, 0.0, 1.0])


def test_step_gradient_signs():
    """ds = qp for saturated-high, qn for saturated-low, round(v/s)-v/s in range."""
    s = jnp.asarray(0.1)
    # Saturated high: d out/d s = qp * gscale.
    g_hi = jax.grad(lambda s: jnp.sum(lsq(jnp.asarray([5.0]), s, -8.0, 7.0)), argnums=0)(s)
    gscale = 1.0 / np.sqrt(1 * 7.0)
    np.testing.assert_allclose(float(g_hi), 7.0 * gscale, rtol=1e-5)
    g_lo = jax.grad(lambda s: jnp.sum(lsq(jnp.asarray([-5.0]), s, -8.0, 7.0)), argnums=0)(s)
    np.testing.assert_allclose(float(g_lo), -8.0 * gscale, rtol=1e-5)
    # In range, v/s = 3.4: ds_elem = round(3.4) - 3.4 = -0.4.
    g_in = jax.grad(lambda s: jnp.sum(lsq(jnp.asarray([0.34]), s, -8.0, 7.0)), argnums=0)(s)
    np.testing.assert_allclose(float(g_in), -0.4 * gscale, rtol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000), bits=st.sampled_from([2, 4, 8]))
def test_codes_within_range(seed, bits):
    w = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * 0.5
    codes = np.asarray(weight_codes(w, 0.05, float(bits)))
    qn, qp = qrange(float(bits), signed=True)
    assert codes.min() >= qn and codes.max() <= qp


def test_init_step_size_positive_and_scales():
    w = jax.random.normal(jax.random.PRNGKey(0), (512,))
    s4 = float(init_step_size(w, 4))
    s2 = float(init_step_size(w, 2))
    assert s4 > 0 and s2 > 0
    # Fewer levels → larger step.
    assert s2 > s4


def test_quantize_weight_idempotent():
    """Quantizing an already-quantized tensor is a no-op."""
    w = jax.random.normal(jax.random.PRNGKey(1), (64,))
    q1 = quantize_weight(w, 0.1, 4.0)
    q2 = quantize_weight(q1, 0.1, 4.0)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)
