"""L2 model semantics: shapes, bits plumbing, training signal, layer tables."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import MODELS


@pytest.fixture(scope="module")
def small_models():
    # Smaller eval batches for test speed; same code paths.
    return MODELS


@pytest.mark.parametrize("name", ["qresnet20", "qsegnet", "qbert"])
def test_layer_table_consistent(name):
    mdef = MODELS[name]
    table = mdef.layer_table()
    assert len(table) == mdef.n_bits()
    # qindex is 0..L-1 in order.
    assert [row["qindex"] for row in table] == list(range(len(table)))
    for row in table:
        assert row["macs"] > 0
        assert row["weight_params"] > 0
    # First layer fixed at 8-bit (paper §3.4.1); head fixed too.
    assert table[0]["fixed_bits"] == 8 or name == "qbert"
    assert table[-1]["fixed_bits"] == 8


@pytest.mark.parametrize("name", ["qresnet20", "qsegnet", "qbert"])
def test_forward_shapes(name):
    mdef = MODELS[name]
    params = mdef.init_params(seed=0)
    x, y = mdef.example_batch(4)
    bits = jnp.full((mdef.n_bits(),), 4.0)
    loss, metric = mdef.loss_metric(params, (x, y), bits)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert 0.0 <= float(metric) <= 1.0


@pytest.mark.parametrize("name", ["qresnet20", "qsegnet", "qbert"])
def test_bits_vector_changes_output(name):
    """Dropping precision must actually change the computation."""
    mdef = MODELS[name]
    params = mdef.init_params(seed=0)
    x, y = mdef.example_batch(2)
    # Use real data-ish inputs so quantization bites.
    if name == "qbert":
        x = jnp.ones_like(x) * 3
    else:
        x = jnp.linspace(0, 1, x.size).reshape(x.shape)
    l4, _ = mdef.loss_metric(params, (x, y), jnp.full((mdef.n_bits(),), 4.0))
    l2, _ = mdef.loss_metric(params, (x, y), jnp.full((mdef.n_bits(),), 2.0))
    assert abs(float(l4) - float(l2)) > 1e-6


def test_train_step_reduces_loss_qresnet():
    mdef = MODELS["qresnet20"]
    params = mdef.init_params(seed=0)
    mom = jax.tree_util.tree_map(jnp.zeros_like, params)
    bits = jnp.full((mdef.n_bits(),), 8.0)
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (8, 32, 32, 3))
    y = (jnp.arange(8) % 10).astype(jnp.int32)
    step = jax.jit(lambda p, m: mdef.train_step(p, m, x, y, 0.05, 0.0, bits))
    losses = []
    for _ in range(20):
        params, mom, loss, _ = step(params, mom)
        losses.append(float(loss))
    # Overfitting one batch must drive loss down (momentum causes an
    # initial transient, hence the longer horizon).
    assert losses[-1] < losses[0], losses


def test_vhv_step_shape_and_determinism():
    mdef = MODELS["qsegnet"]
    params = mdef.init_params(seed=0)
    x, y = mdef.example_batch(2)
    x = jnp.linspace(0, 1, x.size).reshape(x.shape)
    bits = jnp.full((mdef.n_bits(),), 4.0)
    seed = jnp.asarray([3], jnp.int32)
    v1 = mdef.vhv_step(params, x, y, bits, seed)
    v2 = mdef.vhv_step(params, x, y, bits, seed)
    assert v1.shape == (mdef.n_bits(),)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    v3 = mdef.vhv_step(params, x, y, bits, jnp.asarray([4], jnp.int32))
    assert not np.allclose(np.asarray(v1), np.asarray(v3))


def test_eagl_step_matches_host_formula():
    from compile.kernels.ref import entropy_ref
    from compile.quantizer import weight_codes

    mdef = MODELS["qsegnet"]
    params = mdef.init_params(seed=0)
    ents = np.asarray(mdef.eagl_step(params))
    table = mdef.layer_table()
    assert ents.shape == (len(table),)
    # Recompute layer 1 by hand.
    row = table[1]
    node = params
    for part in row["name"].split("."):
        node = node[part]
    b = row["fixed_bits"] or 4
    codes = weight_codes(node["w"], jnp.abs(node["sw"]) + 1e-8, float(b))
    want = float(entropy_ref(codes, 1 << b, -(1 << (b - 1))))
    np.testing.assert_allclose(ents[1], want, rtol=1e-4)


def test_qbert_span_logits_cover_sequence():
    mdef = MODELS["qbert"]
    params = mdef.init_params(seed=0)
    x, y = mdef.example_batch(2)
    bits = jnp.full((mdef.n_bits(),), 4.0)
    loss, pred = mdef.eval_step(params, x, y, bits)
    assert pred.shape == (2, 2)
    assert (np.asarray(pred) >= 0).all() and (np.asarray(pred) < 32).all()


def test_qsegnet_iu_counts_sane():
    mdef = MODELS["qsegnet"]
    params = mdef.init_params(seed=0)
    x, y = mdef.example_batch(2)
    bits = jnp.full((mdef.n_bits(),), 4.0)
    _, iu = mdef.eval_step(params, x, y, bits)
    iu = np.asarray(iu)
    assert iu.shape == (2, 5)
    # intersection <= union, all non-negative.
    assert (iu[0] <= iu[1] + 1e-6).all()
    assert (iu >= 0).all()
    # unions sum >= total pixels (each pixel is in >= 1 class union).
    assert iu[1].sum() >= 2 * 32 * 32
