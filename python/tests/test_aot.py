"""AOT pipeline: manifests match emitted artifacts; checkpoint format
round-trips; HLO text is parseable interchange (structure-level checks —
the full load-and-execute round trip is covered by the Rust integration
tests)."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import CKPT_MAGIC, path_to_name, tensor_specs, to_hlo_text, write_ckpt
from compile.model import MODELS

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_path_naming():
    params = {"a": {"b": jnp.zeros(3)}, "c": jnp.zeros(())}
    specs = tensor_specs(params)
    names = [s["name"] for s in specs]
    assert names == ["a/b", "c"]
    assert specs[0]["shape"] == [3]
    assert specs[0]["dtype"] == "float32"


def test_hlo_text_emission_small_fn():
    lowered = jax.jit(lambda x: (x * 2.0 + 1.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_ckpt_binary_layout(tmp_path):
    tree = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]]), "s": jnp.asarray(0.5)}
    path = tmp_path / "t.ckpt"
    write_ckpt(str(path), tree)
    blob = path.read_bytes()
    assert blob[:8] == CKPT_MAGIC
    (count,) = struct.unpack_from("<I", blob, 8)
    assert count == 2
    # First record: name "s" (dict order is flatten order: "s" < "w").
    (nlen,) = struct.unpack_from("<I", blob, 12)
    name = blob[16 : 16 + nlen].decode()
    assert name == "s"


@pytest.mark.skipif(
    not os.path.isdir(ARTIFACTS) or not os.listdir(ARTIFACTS),
    reason="artifacts not built",
)
@pytest.mark.parametrize("name", ["qresnet20", "qsegnet", "qbert"])
def test_manifest_matches_model(name):
    with open(os.path.join(ARTIFACTS, f"{name}.manifest.json")) as f:
        man = json.load(f)
    mdef = MODELS[name]
    assert man["meta"]["n_bits"] == mdef.n_bits()
    assert len(man["layers"]) == len(mdef.layer_table())
    # Params in manifest must match flatten order of a fresh init.
    fresh = tensor_specs(mdef.init_params(seed=0))
    assert [p["name"] for p in man["params"]] == [p["name"] for p in fresh]
    assert [p["shape"] for p in man["params"]] == [p["shape"] for p in fresh]
    # Every entry's HLO file exists and is non-trivial.
    for entry in man["entries"].values():
        p = os.path.join(ARTIFACTS, entry["file"])
        assert os.path.getsize(p) > 10_000, entry["file"]


@pytest.mark.skipif(
    not os.path.isdir(ARTIFACTS) or not os.path.exists(os.path.join(ARTIFACTS, "qsegnet_init.ckpt")),
    reason="artifacts not built",
)
def test_init_ckpt_loads_back():
    # Parse the emitted checkpoint with a reference reader and compare
    # against a fresh init.
    path = os.path.join(ARTIFACTS, "qsegnet_init.ckpt")
    blob = open(path, "rb").read()
    assert blob[:8] == CKPT_MAGIC
    (count,) = struct.unpack_from("<I", blob, 8)
    mdef = MODELS["qsegnet"]
    fresh = jax.tree_util.tree_flatten_with_path(mdef.init_params(seed=0))[0]
    assert count == len(fresh)
    off = 12
    for (p, leaf) in fresh:
        (nlen,) = struct.unpack_from("<I", blob, off)
        off += 4
        name = blob[off : off + nlen].decode()
        off += nlen
        assert name == path_to_name(p)
        (ndim,) = struct.unpack_from("<I", blob, off)
        off += 4
        dims = struct.unpack_from(f"<{ndim}I", blob, off)
        off += 4 * ndim
        assert list(dims) == list(np.asarray(leaf).shape)
        (blen,) = struct.unpack_from("<Q", blob, off)
        off += 8
        data = np.frombuffer(blob[off : off + blen], dtype="<f4").reshape(dims)
        off += blen
        np.testing.assert_allclose(data, np.asarray(leaf, dtype=np.float32), rtol=1e-6)
    assert off == len(blob)
