"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

The CORE correctness signal for the compiled artifacts — everything the
Rust runtime executes flows through these kernels.  Hypothesis sweeps
shapes, scales, and precisions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.entropy_hist import entropy_pallas, histogram_pallas
from compile.kernels.quant_matmul import quant_matmul, quant_matmul_pallas
from compile.quantizer import qrange

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 3, 16, 64, 200]),
    k=st.sampled_from([4, 32, 64]),
    n=st.sampled_from([2, 48, 128]),
    bits_a=st.sampled_from([2, 4, 8]),
    bits_w=st.sampled_from([2, 4, 8]),
    sx=st.floats(0.01, 0.5),
    sw=st.floats(0.01, 0.5),
)
def test_quant_matmul_matches_ref(m, k, n, bits_a, bits_w, sx, sw):
    x = rand(m * 1000 + k, (m, k))
    w = rand(n * 1000 + k + 1, (k, n))
    qna, qpa = qrange(float(bits_a), signed=True)
    qnw, qpw = qrange(float(bits_w), signed=True)
    got = quant_matmul_pallas(x, w, sx, sw, qna, qpa, qnw, qpw)
    want = ref.quant_matmul_ref(x, w, sx, sw, qna, qpa, qnw, qpw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_quant_matmul_various_tilings():
    """Grid tiling must not change results (same math, different schedule)."""
    x = rand(0, (128, 32))
    w = rand(1, (32, 64))
    outs = []
    for bm, bn in [(32, 16), (64, 64), (128, 64), (128, 128)]:
        outs.append(
            np.asarray(
                quant_matmul_pallas(x, w, 0.1, 0.05, 0.0, 15.0, -8.0, 7.0, bm=bm, bn=bn)
            )
        )
    for o in outs[1:]:
        # Tiles change the f32 accumulation order; equality is to float eps.
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


def test_quant_matmul_gradients_match_lsq_semantics():
    """STE + LSQ gradients: compare against an autodiff-able jnp recreation."""
    from compile.quantizer import lsq

    x = rand(3, (16, 8))
    w = rand(4, (8, 12))
    sx, sw = jnp.asarray(0.11), jnp.asarray(0.07)

    def with_kernel(x, w, sx, sw):
        return jnp.sum(quant_matmul(x, w, sx, sw, 0.0, 15.0, -8.0, 7.0) ** 2)

    def with_lsq(x, w, sx, sw):
        xq = lsq(x, sx, 0.0, 15.0)
        wq = lsq(w, sw, -8.0, 7.0)
        return jnp.sum((xq @ wq) ** 2)

    g1 = jax.grad(with_kernel, argnums=(0, 1, 2, 3))(x, w, sx, sw)
    g2 = jax.grad(with_lsq, argnums=(0, 1, 2, 3))(x, w, sx, sw)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_quant_matmul_saturation():
    """Everything clamps to the max code when |x| >> s * qp."""
    x = jnp.full((4, 4), 100.0)
    w = jnp.full((4, 4), 100.0)
    out = quant_matmul_pallas(x, w, 0.1, 0.1, 0.0, 15.0, -8.0, 7.0)
    np.testing.assert_allclose(np.asarray(out), 4 * 1.5 * 0.7, rtol=1e-6)


def test_quant_matmul_2bit_code_granularity():
    """At 2 bits, outputs only involve codes {-2,-1,0,1} * s."""
    x = rand(7, (8, 8), scale=0.5)
    w = rand(8, (8, 8), scale=0.5)
    out = quant_matmul_pallas(x, w, 0.25, 0.25, -2.0, 1.0, -2.0, 1.0)
    # Exact multiples of s*s = 0.0625 after f32 accumulation.
    scaled = np.asarray(out) / 0.0625
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-4)


# ---------------------------------------------------------------------------
# entropy / histogram
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.sampled_from([17, 256, 1000, 5000]),
    bits=st.sampled_from([2, 3, 4, 8]),
    scale=st.floats(0.02, 0.5),
)
def test_entropy_matches_ref(n, bits, scale):
    w = rand(n, (n,), scale=0.3)
    e = entropy_pallas(w, scale, bits)
    n_bins = 1 << bits
    qp = n_bins // 2 - 1
    qn = -(n_bins // 2)
    codes = jnp.clip(jnp.round(w / scale), qn, qp)
    want = ref.entropy_ref(codes, n_bins, qn)
    np.testing.assert_allclose(float(e), float(want), rtol=1e-4, atol=1e-5)


def test_histogram_counts_everything():
    codes0 = jnp.asarray([0.0, 1.0, 1.0, 3.0, 3.0, 3.0])
    hist = histogram_pallas(codes0, 4, bs=4)  # padding path exercised
    np.testing.assert_allclose(np.asarray(hist), [1, 2, 0, 3])


def test_entropy_uniform_and_constant():
    # Uniform over 16 codes: H = 4 bits.
    w = (jnp.arange(1600) % 16 - 8).astype(jnp.float32) * 0.1
    e = entropy_pallas(w, 0.1, 4)
    assert abs(float(e) - 4.0) < 1e-3
    # Constant: H ≈ 0.
    e0 = entropy_pallas(jnp.zeros(512), 0.1, 4)
    assert float(e0) < 1e-3


@settings(**SETTINGS)
@given(bits=st.sampled_from([2, 4]), seed=st.integers(0, 10_000))
def test_entropy_bounded(bits, seed):
    w = rand(seed, (777,), scale=0.4)
    e = float(entropy_pallas(w, 0.1, bits))
    assert -1e-6 <= e <= bits + 1e-6
