"""L1 Pallas kernel: LSQ fake-quantized matmul — the quantized-GEMM hot-spot.

Every linear layer in the transformer path (q/k/v/o projections and both FFN
matmuls) runs through this kernel, so it lowers into the same HLO artifact
the Rust coordinator executes.

TPU mapping (DESIGN.md §6 Hardware-Adaptation): the paper's deployment
target (NorthPole) performs 2/4/8-bit integer MACs in dedicated silicon.  On
the TPU-shaped Pallas model we express the same computation as

  * VPU elementwise fake-quant of both operands (scale, round, clamp) —
    bit-width dependent clamp bounds arrive as *scalars*, so one kernel
    serves every per-layer precision the knapsack optimizer picks;
  * an MXU matmul over the quantized tiles, f32 accumulate;
  * a ``BlockSpec`` grid over (M/bm, N/bn) with the K dimension VMEM-resident
    — the HBM↔VMEM schedule the paper's silicon does with near-compute SRAM.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated through the interpret path and TPU
efficiency is estimated analytically (EXPERIMENTS.md §Perf).

Backward pass: the kernel is wrapped in a ``custom_vjp`` whose bwd is pure
jnp (STE for tensors, LSQ gradient for the step sizes), so fwd runs the
Pallas kernel while training still differentiates through it.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (grid must tile evenly)."""
    t = min(dim, target)
    while dim % t != 0:
        t -= 1
    return t


def _qmm_kernel(q_ref, x_ref, w_ref, o_ref):
    """One (bm, bn) output tile: fake-quant both operands, MXU matmul.

    q_ref holds the 6 quantization scalars [sx, sw, qnx, qpx, qnw, qpw]
    (scalar-prefetch-style operand — SMEM on real TPU).
    """
    sx, sw = q_ref[0], q_ref[1]
    qnx, qpx = q_ref[2], q_ref[3]
    qnw, qpw = q_ref[4], q_ref[5]
    xq = jnp.clip(jnp.round(x_ref[...] / sx), qnx, qpx) * sx
    wq = jnp.clip(jnp.round(w_ref[...] / sw), qnw, qpw) * sw
    # f32 accumulate on the MXU (preferred_element_type pins the accumulator).
    o_ref[...] = jax.lax.dot_general(
        xq, wq,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def quant_matmul_pallas(x, w, sx, sw, qnx, qpx, qnw, qpw, *, bm=256, bn=128):
    """Raw Pallas forward: y = fq(x; sx) @ fq(w; sw), tiled over (M, N).

    x: (M, K) activations, w: (K, N) weights, scales/bounds: f32 scalars.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_tile(m, bm)
    bn = _pick_tile(n, bn)
    qparams = jnp.stack(
        [jnp.asarray(v, jnp.float32).reshape(()) for v in
         (sx, sw, qnx, qpx, qnw, qpw)]
    )
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[
            # Quantization scalars: replicated to every grid step.
            pl.BlockSpec((6,), lambda i, j: (0,)),
            # x tile: row block i, full K resident in VMEM.
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            # w tile: full K, column block j.
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(qparams, x, w)


@jax.custom_vjp
def quant_matmul(x, w, sx, sw, qnx, qpx, qnw, qpw):
    """Differentiable LSQ-quantized matmul (Pallas fwd, jnp bwd).

    Gradients: STE through both fake-quant ops for x and w; LSQ step-size
    gradients for sx and sw; zero for the clamp bounds (precision is chosen
    by the knapsack optimizer, not SGD).
    """
    return quant_matmul_pallas(x, w, sx, sw, qnx, qpx, qnw, qpw)


def _qmm_fwd(x, w, sx, sw, qnx, qpx, qnw, qpw):
    y = quant_matmul_pallas(x, w, sx, sw, qnx, qpx, qnw, qpw)
    return y, (x, w, sx, sw, qnx, qpx, qnw, qpw)


def _lsq_partials(v, s, qn, qp):
    """(fake-quantized v, STE mask, elementwise d fq / d s)."""
    vs = v / s
    in_range = jnp.logical_and(vs >= qn, vs <= qp)
    fq = jnp.clip(jnp.round(vs), qn, qp) * s
    ds = jnp.where(vs < qn, qn, jnp.where(vs > qp, qp, jnp.round(vs) - vs))
    return fq, in_range, ds


def _qmm_bwd(res, gy):
    x, w, sx, sw, qnx, qpx, qnw, qpw = res
    xq, x_in, dsx_elem = _lsq_partials(x, sx, qnx, qpx)
    wq, w_in, dsw_elem = _lsq_partials(w, sw, qnw, qpw)
    gx_q = gy @ wq.T          # d y / d xq
    gw_q = xq.T @ gy          # d y / d wq
    gx = jnp.where(x_in, gx_q, 0.0)
    gw = jnp.where(w_in, gw_q, 0.0)
    gsx_scale = 1.0 / jnp.sqrt(jnp.asarray(x.size, jnp.float32) * jnp.maximum(qpx, 1.0))
    gsw_scale = 1.0 / jnp.sqrt(jnp.asarray(w.size, jnp.float32) * jnp.maximum(qpw, 1.0))
    gsx = jnp.sum(gx_q * dsx_elem) * gsx_scale
    gsw = jnp.sum(gw_q * dsw_elem) * gsw_scale
    z = jnp.zeros_like(qnx)
    return gx, gw, gsx, gsw, z, z, z, z


quant_matmul.defvjp(_qmm_fwd, _qmm_bwd)
