"""L1 Pallas kernel: histogram-of-codes + Shannon entropy — the EAGL metric.

EAGL (paper Eq. 1-3, Algorithm 2) scores each layer by the entropy of the
empirical distribution of its quantized weight codes.  This kernel fuses the
bincount and the entropy reduction so the whole metric is one pass over the
weights: for each of the ``n_bins`` codes it counts matches (VPU compare +
reduce), normalizes, and accumulates -p*log2(p).

The weight vector is tiled over a 1-D grid (``bs`` elements per step) with a
VMEM-resident (n_bins,) histogram accumulator carried across grid steps —
the standard Pallas reduction idiom (output revisited by every grid step).

The Rust-native EAGL implementation (rust/src/eagl/) is cross-checked
against this kernel through the ``eagl_step`` artifact and against
``ref.entropy_ref`` in pytest.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(codes_ref, hist_ref):
    """Accumulate counts of each code value in this tile into hist_ref."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    codes = codes_ref[...]                      # (bs,) f32 integer-valued
    n_bins = hist_ref.shape[0]
    # Bin index of each element; one-hot compare against all bins (VPU).
    bins = jax.lax.iota(jnp.float32, n_bins)    # 0..n_bins-1
    # codes are shifted to 0-based before the call.
    onehot = (codes[:, None] == bins[None, :]).astype(jnp.float32)
    hist_ref[...] += jnp.sum(onehot, axis=0)


def histogram_pallas(codes0, n_bins: int, *, bs: int = 4096):
    """Histogram of 0-based integer codes (f32), tiled over a 1-D grid."""
    flat = codes0.reshape(-1)
    n = flat.shape[0]
    # Pad to a multiple of the block with an out-of-range sentinel that
    # matches no bin.
    pad = (-n) % bs
    if pad:
        flat = jnp.concatenate([flat, jnp.full((pad,), -1.0, jnp.float32)])
    grid = (flat.shape[0] // bs,)
    return pl.pallas_call(
        _hist_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bs,), lambda i: (i,))],
        # Accumulator revisited by every grid step.
        out_specs=pl.BlockSpec((n_bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_bins,), jnp.float32),
        interpret=True,
    )(flat)


def entropy_pallas(w, s, bits_static: int, *, eps: float = 1e-10):
    """EAGL entropy (bits) of a weight tensor quantized at ``bits_static``.

    Unlike the matmul kernel, the bin count 2^b is a *shape*, so the
    bit-width is static here; the eagl_step artifact is lowered per
    candidate precision (the paper only ever needs b = the checkpoint's
    precision, Algorithm 2).
    """
    n_bins = 1 << int(bits_static)
    qp = float(n_bins // 2 - 1)
    qn = -float(n_bins // 2)
    codes = jnp.clip(jnp.round(w / s), qn, qp) - qn   # 0-based
    hist = histogram_pallas(codes.astype(jnp.float32), n_bins)
    p = hist / jnp.asarray(codes.size, jnp.float32) + eps
    return -jnp.sum(p * jnp.log2(p))
