"""Pure-jnp oracles for the Pallas kernels.

Every L1 kernel has a reference here; pytest asserts allclose between the
kernel (interpret=True) and these functions over swept shapes/precisions
(python/tests/test_kernels.py).  The Rust EAGL implementation is *also*
cross-checked against ``entropy_ref`` via the eagl_step artifact.
"""

import jax.numpy as jnp


def fake_quant_ref(v, s, qn, qp):
    """clamp(round(v/s), qn, qp) * s — the LSQ forward."""
    return jnp.clip(jnp.round(v / s), qn, qp) * s


def quant_matmul_ref(x, w, sx, sw, qnx, qpx, qnw, qpw):
    """Fake-quantize both operands, then matmul, f32 accumulate."""
    xq = fake_quant_ref(x, sx, qnx, qpx)
    wq = fake_quant_ref(w, sw, qnw, qpw)
    return jnp.matmul(xq, wq)


def histogram_ref(codes, n_bins, code_min):
    """Normalized histogram of integer codes (paper Appendix E bincount)."""
    idx = (codes.reshape(-1) - code_min).astype(jnp.int32)
    hist = jnp.zeros((n_bins,), jnp.float32).at[idx].add(1.0)
    return hist / codes.size


def entropy_ref(codes, n_bins, code_min, eps=1e-10):
    """Shannon entropy (bits) of the empirical code distribution (Eq. 3).

    Matches the paper's Appendix E: entropy of (p + eps) so empty bins
    contribute ~0.
    """
    p = histogram_ref(codes, n_bins, code_min) + eps
    return -jnp.sum(p * jnp.log2(p))
