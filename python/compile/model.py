"""L2 entry points: the jitted step functions the Rust coordinator executes.

Four entry points per model, each AOT-lowered to one HLO artifact:

  * ``train_step``  — fwd/bwd + SGD-momentum(+wd) update, returns
                      (params', mom', loss, metric).  One fused graph; no
                      per-layer host round-trips on the fine-tune hot path.
  * ``eval_step``   — loss + model-specific evaluation outputs (correct
                      count / IoU counts / span predictions).
  * ``vhv_step``    — one Hutchinson sample: v ~ Rademacher(seed), returns
                      per-selectable-layer v·(Hv) over the weight tensors —
                      the HAWQ-v3 average-Hessian-trace estimator
                      (Appendix C re-implementation).
  * ``eagl_step``   — per-layer EAGL entropies via the L1 histogram kernel
                      (cross-checks the Rust-native EAGL path).

Per-layer precision is a runtime f32 ``bits`` vector, so a single artifact
set serves the entire budget sweep.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels.entropy_hist import entropy_pallas
from .models import qbert, qresnet, qsegnet

MOMENTUM = 0.9


class ModelDef:
    """Binds a model module + config to the generic step functions."""

    def __init__(self, name, module, cfg, train_batch, eval_batch):
        self.name = name
        self.module = module
        self.cfg = cfg
        self.train_batch = train_batch
        self.eval_batch = eval_batch

    # -- shapes ------------------------------------------------------------
    def example_batch(self, batch_size):
        cfg = self.cfg
        if self.name.startswith("qresnet"):
            x = jnp.zeros((batch_size, cfg["image"], cfg["image"], 3), jnp.float32)
            y = jnp.zeros((batch_size,), jnp.int32)
        elif self.name == "qsegnet":
            x = jnp.zeros((batch_size, cfg["image"], cfg["image"], 3), jnp.float32)
            y = jnp.zeros((batch_size, cfg["image"], cfg["image"]), jnp.int32)
        else:  # qbert
            x = jnp.zeros((batch_size, cfg["seq"]), jnp.int32)
            y = jnp.zeros((batch_size, 2), jnp.int32)
        return x, y

    def init_params(self, seed=0):
        return self.module.init_params(jax.random.PRNGKey(seed), self.cfg)

    def layer_table(self):
        return self.module.layer_table(self.cfg)

    def n_bits(self):
        return self.module.num_bits_entries(self.cfg)

    # -- steps ---------------------------------------------------------------
    def loss_metric(self, params, batch, bits):
        return self.module.loss_and_metric(params, batch, bits, self.cfg)

    def train_step(self, params, mom, x, y, lr, wd, bits):
        def loss_fn(p):
            return self.loss_metric(p, (x, y), bits)

        (loss, metric), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

        # SGD momentum; weight decay on weight tensors only (not step sizes,
        # biases, or norm parameters) — standard LSQ practice.
        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        treedef = jax.tree_util.tree_structure(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(mom)
        new_p, new_m = [], []
        for (path, p), g, m in zip(flat_p, flat_g, flat_m):
            keyname = jax.tree_util.keystr(path)
            is_weight = keyname.endswith("['w']") or keyname.endswith("['embed']") \
                or keyname.endswith("['pos']")
            g_eff = g + wd * p if is_weight else g
            m_new = MOMENTUM * m + g_eff
            new_p.append(p - lr * m_new)
            new_m.append(m_new)
        params_new = jax.tree_util.tree_unflatten(treedef, new_p)
        mom_new = jax.tree_util.tree_unflatten(treedef, new_m)
        return params_new, mom_new, loss, metric

    def eval_step(self, params, x, y, bits):
        return self.module.eval_outputs(params, (x, y), bits, self.cfg)

    def _weight_leaves(self, params):
        """(path, leaf) for quantizable-layer weight tensors, qindex order."""
        table = self.layer_table()
        out = []
        for row in table:
            name = row["name"]
            node = params
            for part in name.split("."):
                node = node[part]
            out.append(node["w"])
        return out

    def vhv_step(self, params, x, y, bits, seed):
        """One Hutchinson v·Hv per selectable layer (HAWQ-v3 trace est.).

        Traced with the pure-jnp linear path (see models.common.REF_LINEAR):
        second-order autodiff has no rule for the Pallas custom_vjp, and the
        two paths are numerically identical.
        """
        from .models import common
        common.REF_LINEAR = True
        try:
            return self._vhv_inner(params, x, y, bits, seed)
        finally:
            common.REF_LINEAR = False

    def _vhv_inner(self, params, x, y, bits, seed):
        ws = self._weight_leaves(params)

        def loss_of_ws(ws_new):
            p = _replace_weights(params, self.layer_table(), ws_new)
            loss, _ = self.loss_metric(p, (x, y), bits)
            return loss

        key = jax.random.key(seed[0])
        keys = jax.random.split(key, len(ws))
        vs = [jax.random.rademacher(k, w.shape, jnp.float32)
              for k, w in zip(keys, ws)]
        grad_fn = jax.grad(loss_of_ws)

        # Double-reverse HVP (custom_vjp ops have no JVP rule):
        # Hv = grad_w <grad(loss)(w), v>.
        def gdotv(ws_new):
            g = grad_fn(ws_new)
            return sum(jnp.vdot(gi, vi) for gi, vi in zip(g, vs))

        hvs = jax.grad(gdotv)(ws)
        return jnp.stack([jnp.sum(v * hv) for v, hv in zip(vs, hvs)])

    def eagl_step(self, params, ckpt_bits=4):
        """Per-layer EAGL entropy at the checkpoint precision (Alg. 2)."""
        ents = []
        table = self.layer_table()
        for row in table:
            node = params
            for part in name_parts(row["name"]):
                node = node[part]
            s = jnp.abs(node["sw"]) + 1e-8
            b = row["fixed_bits"] or ckpt_bits
            ents.append(entropy_pallas(node["w"], s, b))
        return jnp.stack(ents)


def name_parts(name):
    return name.split(".")


def _replace_weights(params, table, new_ws):
    """Functionally replace each quantizable layer's 'w' leaf."""

    def set_in(d, parts, value):
        node = d
        for part in parts[:-1]:
            node = node[part]
        inner = dict(node[parts[-1]])
        inner["w"] = value
        node[parts[-1]] = inner

    out = _deep_dict_copy(params)
    for row, w in zip(table, new_ws):
        set_in(out, name_parts(row["name"]), w)
    return out


def _deep_dict_copy(d):
    if isinstance(d, dict):
        return {k: _deep_dict_copy(v) for k, v in d.items()}
    return d


# ---------------------------------------------------------------------------
# Registry — sizes chosen for the single-CPU testbed (DESIGN.md §3).
# ---------------------------------------------------------------------------

def build_registry():
    return {
        "qresnet20": ModelDef("qresnet20", qresnet,
                              qresnet.make_config(depth=20),
                              train_batch=64, eval_batch=256),
        "qresnet32": ModelDef("qresnet32", qresnet,
                              qresnet.make_config(depth=32),
                              train_batch=64, eval_batch=256),
        "qsegnet": ModelDef("qsegnet", qsegnet, qsegnet.make_config(),
                            train_batch=16, eval_batch=64),
        "qbert": ModelDef("qbert", qbert, qbert.make_config(),
                          train_batch=32, eval_batch=128),
    }


MODELS = build_registry()
