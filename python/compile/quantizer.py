"""LSQ quantizer (Esser et al., 2020) as a JAX custom_vjp.

The paper fine-tunes every mixed-precision network with LSQ (§3.4.3): both
weights and activations are fake-quantized with a *learned* per-tensor step
size.  The forward pass is

    q(v; s) = clamp(round(v / s), qn, qp) * s

and the backward pass uses the straight-through estimator for ``v`` and the
LSQ gradient for ``s``:

    dq/dv = 1                         if qn <= v/s <= qp else 0
    dq/ds = round(v/s) - v/s          if qn <= v/s <= qp
          = qn                        if v/s < qn
          = qp                        if v/s > qp

scaled by the LSQ gradient scale g = 1 / sqrt(numel * qp).

Bit-widths enter as *traced* f32 scalars (qn/qp are computed from them), so
a single lowered HLO artifact serves every per-layer precision
configuration — the Rust coordinator feeds a per-layer bits vector at
runtime (DESIGN.md §2).
"""

from functools import partial

import jax
import jax.numpy as jnp


def qrange(bits, signed: bool):
    """(qn, qp) for a given (possibly traced) bit-width.

    Signed symmetric: [-2^(b-1), 2^(b-1)-1]; unsigned: [0, 2^b - 1].
    ``bits`` may be a traced f32 scalar.
    """
    bits = jnp.asarray(bits, jnp.float32)
    if signed:
        qp = jnp.exp2(bits - 1.0) - 1.0
        qn = -jnp.exp2(bits - 1.0)
    else:
        qp = jnp.exp2(bits) - 1.0
        qn = jnp.zeros_like(qp)
    return qn, qp


@partial(jax.custom_vjp, nondiff_argnums=())
def lsq(v, s, qn, qp):
    """LSQ fake-quantization. Differentiable in ``v`` (STE) and ``s`` (LSQ)."""
    vs = v / s
    return jnp.clip(jnp.round(vs), qn, qp) * s


def _lsq_fwd(v, s, qn, qp):
    return lsq(v, s, qn, qp), (v, s, qn, qp)


def _lsq_bwd(res, g):
    v, s, qn, qp = res
    vs = v / s
    in_range = jnp.logical_and(vs >= qn, vs <= qp)
    # STE for the tensor.
    dv = jnp.where(in_range, g, 0.0)
    # LSQ gradient for the step size.
    ds_elem = jnp.where(vs < qn, qn, jnp.where(vs > qp, qp, jnp.round(vs) - vs))
    gscale = 1.0 / jnp.sqrt(jnp.asarray(v.size, jnp.float32) * jnp.maximum(qp, 1.0))
    ds = jnp.sum(g * ds_elem) * gscale
    # qn/qp come from the bits vector; precision choice is not optimized by
    # SGD in this paper, so their cotangents are zero.
    return (
        dv,
        ds.reshape(jnp.shape(s)),
        jnp.zeros(jnp.shape(qn), jnp.float32),
        jnp.zeros(jnp.shape(qp), jnp.float32),
    )


lsq.defvjp(_lsq_fwd, _lsq_bwd)


def quantize_weight(w, s, bits):
    """Signed symmetric LSQ fake-quantization of a weight tensor."""
    qn, qp = qrange(bits, signed=True)
    return lsq(w, s, qn, qp)


def quantize_act(a, s, bits, signed=False):
    """LSQ fake-quantization of an activation tensor.

    Post-ReLU activations use the unsigned range (LSQ practice); transformer
    activations (which may be negative) use the signed range.
    """
    qn, qp = qrange(bits, signed=signed)
    return lsq(a, s, qn, qp)


def weight_codes(w, s, bits):
    """Integer codes of a quantized weight tensor (no STE — analysis only).

    These are the values whose empirical distribution EAGL (Eq. 1-3) takes
    the entropy of.  Matches the paper's Appendix E snippet.
    """
    qn, qp = qrange(bits, signed=True)
    return jnp.clip(jnp.round(w / s), qn, qp)


def init_step_size(w, bits) -> float:
    """LSQ step-size init: 2*mean(|w|)/sqrt(qp) (Esser et al., 2020)."""
    _, qp = qrange(float(bits), signed=True)
    return 2.0 * jnp.mean(jnp.abs(w)) / jnp.sqrt(qp)
