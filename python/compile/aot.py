"""AOT compile path: lower every model entry point to HLO text + manifest.

This is the ONLY place Python touches the system: ``make artifacts`` runs it
once; afterwards the Rust binary is self-contained.  Per the image's
interchange constraint we emit HLO **text**, not a serialized
HloModuleProto — jax >= 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Per model we emit:
  artifacts/<model>_train_step.hlo.txt   (params, mom, x, y, lr, wd, bits)
  artifacts/<model>_eval_step.hlo.txt    (params, x, y, bits)
  artifacts/<model>_vhv_step.hlo.txt     (params, x, y, bits, seed)
  artifacts/<model>_eagl_step.hlo.txt    (params)
  artifacts/<model>.manifest.json        flat input/output order, layer table
  artifacts/<model>_init.ckpt            seed-0 initial checkpoint (MPQCKPT1)

Usage: python -m compile.aot --out ../artifacts [--models qresnet20,...]
"""

import argparse
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import MODELS

CKPT_MAGIC = b"MPQCKPT1"


# ---------------------------------------------------------------------------
# HLO text lowering (the gen_hlo.py recipe)
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Naming / manifest helpers
# ---------------------------------------------------------------------------

def path_to_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        else:
            parts.append(str(k))
    return "/".join(parts)


def tensor_specs(tree):
    """[{name, shape, dtype}] in jax flatten order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        out.append({
            "name": path_to_name(path),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        })
    return out


def write_ckpt(path, tree):
    """MPQCKPT1: magic, u32 count, then (name, dims, f32/i32 data) records."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    with open(path, "wb") as f:
        f.write(CKPT_MAGIC)
        f.write(struct.pack("<I", len(leaves)))
        for p, leaf in leaves:
            name = path_to_name(p).encode()
            # NB: np.ascontiguousarray would promote 0-d arrays to 1-d and
            # corrupt scalar step-size shapes; tobytes() below already
            # yields a C-order copy.
            arr = np.asarray(leaf, dtype=np.float32)
            f.write(struct.pack("<I", len(name)))
            f.write(name)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            data = arr.tobytes()
            f.write(struct.pack("<Q", len(data)))
            f.write(data)


# ---------------------------------------------------------------------------
# Per-model lowering
# ---------------------------------------------------------------------------

def lower_model(mdef, outdir):
    name = mdef.name
    params = mdef.init_params(seed=0)
    mom = jax.tree_util.tree_map(jnp.zeros_like, params)
    xt, yt = mdef.example_batch(mdef.train_batch)
    xe, ye = mdef.example_batch(mdef.eval_batch)
    nbits = mdef.n_bits()
    bits = jnp.full((nbits,), 4.0, jnp.float32)
    lr = jnp.asarray(0.01, jnp.float32)
    wd = jnp.asarray(1e-4, jnp.float32)
    seed = jnp.zeros((1,), jnp.int32)

    entries = {}

    def emit(entry, fn, args, order, outputs):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}_{entry}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        entries[entry] = {"file": fname, "order": order, "outputs": outputs}
        print(f"  {fname}: {len(text) / 1e6:.2f} MB")

    emit("train_step",
         lambda p, m, x, y, l, w, b: mdef.train_step(p, m, x, y, l, w, b),
         (params, mom, xt, yt, lr, wd, bits),
         ["params", "mom", "x", "y", "lr", "wd", "bits"],
         ["params", "mom", "loss", "metric"])
    emit("eval_step",
         lambda p, x, y, b: mdef.eval_step(p, x, y, b),
         (params, xe, ye, bits),
         ["params", "x", "y", "bits"],
         ["loss", "evalout"])
    emit("vhv_step",
         lambda p, x, y, b, s: mdef.vhv_step(p, x, y, b, s),
         (params, xt, yt, bits, seed),
         ["params", "x", "y", "bits", "seed"],
         ["vhv"])
    emit("eagl_step",
         lambda p: mdef.eagl_step(p),
         (params,),
         ["params"],
         ["entropies"])

    evalout = np.asarray(mdef.eval_step(params, xe, ye, bits)[1])
    manifest = {
        "model": name,
        "params": tensor_specs(params),
        "entries": entries,
        "layers": mdef.layer_table(),
        "meta": {
            "n_bits": nbits,
            "train_batch": mdef.train_batch,
            "eval_batch": mdef.eval_batch,
            "task": ("cls" if name.startswith("qresnet")
                     else "seg" if name == "qsegnet" else "span"),
            "x_train_shape": list(np.asarray(xt).shape),
            "y_train_shape": list(np.asarray(yt).shape),
            "x_eval_shape": list(np.asarray(xe).shape),
            "y_eval_shape": list(np.asarray(ye).shape),
            "x_dtype": str(np.asarray(xt).dtype),
            "y_dtype": str(np.asarray(yt).dtype),
            "evalout_shape": list(evalout.shape),
            "cfg": mdef.cfg,
        },
    }
    with open(os.path.join(outdir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    write_ckpt(os.path.join(outdir, f"{name}_init.ckpt"), params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=None,
                    help="comma-separated subset (default: all)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    names = args.models.split(",") if args.models else list(MODELS)
    for name in names:
        print(f"lowering {name} ...")
        lower_model(MODELS[name], args.out)
    # Build stamp so `make artifacts` is a no-op when inputs are unchanged.
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")
    print("done")


if __name__ == "__main__":
    main()
