"""qbert — small transformer encoder + span-extraction head (BERT/SQuAD
analog, DESIGN.md §3).

Every linear layer (q, k, v, o, ff1, ff2 per block) is quantized through the
L1 Pallas ``quant_matmul`` kernel and is a selectable 2/4-bit knapsack item.
Embeddings, LayerNorms, and the attention score/value matmuls stay full
precision (standard BERT-quantization practice, matches the paper's W/A
accounting).  The span head — the input to the softmax — is fixed at 8-bit
(paper §4.3).

Task: synthetic "needle" span QA — the answer span is positionally encoded
by a marker motif in the token stream; the model predicts start and end
indices; F1 is the SQuAD-style token-overlap F1 computed Rust-side from the
predictions eval_outputs returns.
"""

import jax
import jax.numpy as jnp

from .common import layer_entry, linear_params, layer_norm, qlinear


def make_config(vocab=32, seq=32, d=64, blocks=4, heads=4, ffn=128):
    return {
        "name": "qbert",
        "vocab": vocab, "seq": seq, "d": d,
        "blocks": blocks, "heads": heads, "ffn": ffn,
    }


_BLOCK_LINEARS = ["q", "k", "v", "o", "ff1", "ff2"]


def init_params(rng, cfg):
    d, ffn, v, s = cfg["d"], cfg["ffn"], cfg["vocab"], cfg["seq"]
    nkeys = 3 + cfg["blocks"] * len(_BLOCK_LINEARS)
    keys = iter(jax.random.split(rng, nkeys))
    params = {
        "embed": jax.random.normal(next(keys), (v, d)) * 0.02,
        "pos": jax.random.normal(next(keys), (s, d)) * 0.02,
    }
    for bi in range(cfg["blocks"]):
        blk = {}
        for lin in _BLOCK_LINEARS:
            din = d if lin != "ff2" else ffn
            dout = d if lin not in ("ff1",) else ffn
            blk[lin] = linear_params(next(keys), din, dout)
        blk["ln1"] = {"gamma": jnp.ones((d,)), "beta": jnp.zeros((d,))}
        blk["ln2"] = {"gamma": jnp.ones((d,)), "beta": jnp.zeros((d,))}
        params[f"blk{bi}"] = blk
    params["span"] = linear_params(next(keys), d, 2, bits_init=8)
    params["ln_f"] = {"gamma": jnp.ones((d,)), "beta": jnp.zeros((d,))}
    return params


def layer_table(cfg):
    d, ffn, s = cfg["d"], cfg["ffn"], cfg["seq"]
    rows, qi = [], 0
    dims = {"q": (d, d), "k": (d, d), "v": (d, d), "o": (d, d),
            "ff1": (d, ffn), "ff2": (ffn, d)}
    for bi in range(cfg["blocks"]):
        for lin in _BLOCK_LINEARS:
            din, dout = dims[lin]
            rows.append(layer_entry(
                f"blk{bi}.{lin}", "linear", qi, f"blk{bi}.{lin}",
                s * din * dout, din * dout, None, din, dout))
            qi += 1
    rows.append(layer_entry("span", "linear", qi, "span", s * d * 2, d * 2,
                            8, d, 2))
    return rows


def num_bits_entries(cfg):
    return cfg["blocks"] * len(_BLOCK_LINEARS) + 1


def forward(params, tokens, bits, cfg):
    """tokens: (B, S) int32; returns (B, S, 2) start/end logits."""
    d, nh = cfg["d"], cfg["heads"]
    hd = d // nh
    b, s = tokens.shape
    h = params["embed"][tokens] + params["pos"][None, :, :]
    qi = 0

    def nb():
        nonlocal qi
        v = bits[qi]
        qi += 1
        return v

    for bi in range(cfg["blocks"]):
        blk = params[f"blk{bi}"]
        x = layer_norm(blk["ln1"], h)
        q = qlinear(blk["q"], x, nb()).reshape(b, s, nh, hd)
        k = qlinear(blk["k"], x, nb()).reshape(b, s, nh, hd)
        v = qlinear(blk["v"], x, nb()).reshape(b, s, nh, hd)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
        h = h + qlinear(blk["o"], ctx, nb())
        x = layer_norm(blk["ln2"], h)
        y = jax.nn.gelu(qlinear(blk["ff1"], x, nb()))
        h = h + qlinear(blk["ff2"], y, nb())
    h = layer_norm(params["ln_f"], h)
    return qlinear(params["span"], h, nb())


def loss_and_metric(params, batch, bits, cfg):
    """CE over start + end positions; metric = mean start/end exact match."""
    tokens, span = batch            # span: (B, 2) int32 [start, end]
    logits = forward(params, tokens, bits, cfg)     # (B, S, 2)
    logp = jax.nn.log_softmax(logits, axis=1)
    ls = -jnp.mean(jnp.take_along_axis(logp[:, :, 0], span[:, :1], axis=1))
    le = -jnp.mean(jnp.take_along_axis(logp[:, :, 1], span[:, 1:], axis=1))
    pred_s = jnp.argmax(logits[:, :, 0], axis=1)
    pred_e = jnp.argmax(logits[:, :, 1], axis=1)
    em = 0.5 * (jnp.mean((pred_s == span[:, 0]).astype(jnp.float32))
                + jnp.mean((pred_e == span[:, 1]).astype(jnp.float32)))
    return ls + le, em


def eval_outputs(params, batch, bits, cfg):
    """(loss, predictions (B, 2) f32) — Rust computes token-overlap F1."""
    tokens, span = batch
    logits = forward(params, tokens, bits, cfg)
    logp = jax.nn.log_softmax(logits, axis=1)
    ls = -jnp.mean(jnp.take_along_axis(logp[:, :, 0], span[:, :1], axis=1))
    le = -jnp.mean(jnp.take_along_axis(logp[:, :, 1], span[:, 1:], axis=1))
    pred = jnp.stack([jnp.argmax(logits[:, :, 0], axis=1),
                      jnp.argmax(logits[:, :, 1], axis=1)], axis=1)
    return ls + le, pred.astype(jnp.float32)
