# L2 QAT model definitions (qresnet / qsegnet / qbert).
