"""qsegnet — small encoder–decoder segmentation CNN, the PSPNet analog.

Encoder: stem → 2 strided stages; bottleneck context conv (the PSP-pyramid
stand-in: a dilated 3x3 that enlarges the receptive field); decoder: 2
nearest-upsample + conv stages; 1x1 classifier head.

Stem and head fixed at 8-bit; everything else selectable.  ALPS uses the
*loss* as the gain signal for this model (paper Algorithm 1's PSPNet
branch); mIoU is accumulated Rust-side from the per-class
intersection/union counts eval_outputs returns.
"""

import jax
import jax.numpy as jnp

from .common import conv_params, layer_entry, norm_params, group_norm, qconv


def make_config(num_classes=5, image=32, widths=(16, 32, 64)):
    return {
        "name": "qsegnet",
        "num_classes": num_classes,
        "image": image,
        "widths": list(widths),
    }


_LAYERS = [
    # name,       kind,  k, stride, dilation
    ("stem",      "conv", 3, 1, 1),
    ("enc1",      "conv", 3, 2, 1),
    ("enc2",      "conv", 3, 1, 1),
    ("enc3",      "conv", 3, 2, 1),
    ("context",   "conv", 3, 1, 2),
    ("dec1",      "conv", 3, 1, 1),   # after 2x upsample
    ("dec2",      "conv", 3, 1, 1),   # after 2x upsample
    ("head",      "conv", 1, 1, 1),
]


def _channels(cfg):
    w = cfg["widths"]
    nc = cfg["num_classes"]
    return {
        "stem": (3, w[0]), "enc1": (w[0], w[1]), "enc2": (w[1], w[1]),
        "enc3": (w[1], w[2]), "context": (w[2], w[2]),
        "dec1": (w[2], w[1]), "dec2": (w[1], w[0]), "head": (w[0], nc),
    }


def init_params(rng, cfg):
    ch = _channels(cfg)
    keys = jax.random.split(rng, len(_LAYERS))
    params = {}
    for (name, _, k, _, _), key in zip(_LAYERS, keys):
        cin, cout = ch[name]
        bits0 = 8 if name in ("stem", "head") else 4
        params[name] = conv_params(key, k, k, cin, cout, bits_init=bits0)
        if name != "head":
            params[name + "_norm"] = norm_params(cout)
    return params


def layer_table(cfg):
    ch = _channels(cfg)
    img = cfg["image"]
    rows = []
    hw = img
    for qi, (name, kind, k, stride, _dil) in enumerate(_LAYERS):
        cin, cout = ch[name]
        if name == "dec1":
            hw = img // 2       # upsampled before the conv
        if name == "dec2":
            hw = img
        hw_out = hw // stride
        fixed = 8 if name in ("stem", "head") else None
        rows.append(layer_entry(
            name, kind, qi, name, hw_out * hw_out * cin * cout * k * k,
            cin * cout * k * k, fixed, cin, cout))
        hw = hw_out
    return rows


def num_bits_entries(cfg):
    return len(_LAYERS)


def _upsample2(x):
    b, h, w, c = x.shape
    x = jnp.broadcast_to(x[:, :, None, :, None, :], (b, h, 2, w, 2, c))
    return x.reshape(b, 2 * h, 2 * w, c)


def forward(params, x, bits, cfg):
    """x: (B, H, W, 3); returns per-pixel logits (B, H, W, num_classes)."""
    dil = {name: d for name, _, _, _, d in _LAYERS}
    stride = {name: s for name, _, _, s, _ in _LAYERS}
    h = x
    for qi, (name, _, _, _, _) in enumerate(_LAYERS):
        if name in ("dec1", "dec2"):
            h = _upsample2(h)
        p = params[name]
        if dil[name] > 1:
            # Dilated context conv: same quantization path, dilated window.
            from ..quantizer import quantize_act, quantize_weight
            from .common import _safe
            sa, sw = _safe(p["sa"]), _safe(p["sw"])
            hq = quantize_act(h, sa, bits[qi], signed=False)
            wq = quantize_weight(p["w"], sw, bits[qi])
            h = jax.lax.conv_general_dilated(
                hq, wq, (1, 1), "SAME", rhs_dilation=(dil[name],) * 2,
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
        else:
            h = qconv(p, h, bits[qi], stride[name])
        if name != "head":
            h = jax.nn.relu(group_norm(params[name + "_norm"], h))
    return h


def loss_and_metric(params, batch, bits, cfg):
    """Pixel cross-entropy + pixel accuracy. batch = (x, y_int32 (B,H,W))."""
    x, y = batch
    logits = forward(params, x, bits, cfg)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc


def eval_outputs(params, batch, bits, cfg):
    """(loss, iu_counts (2, C)) — row 0 intersection, row 1 union, per class.

    Rust sums these across eval batches and reports
    mIoU = mean_c inter_c / union_c (paper Fig. 4 metric).
    """
    x, y = batch
    nc = cfg["num_classes"]
    logits = forward(params, x, bits, cfg)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))
    pred = jnp.argmax(logits, axis=-1)
    classes = jnp.arange(nc)[:, None, None, None]
    pm = pred[None] == classes
    ym = y[None] == classes
    inter = jnp.sum(jnp.logical_and(pm, ym), axis=(1, 2, 3)).astype(jnp.float32)
    union = jnp.sum(jnp.logical_or(pm, ym), axis=(1, 2, 3)).astype(jnp.float32)
    return loss, jnp.stack([inter, union])
