"""qresnet — CIFAR-style residual CNN, the ResNet-50/101 analog (DESIGN.md §3).

depth = 6n+2 (He et al. CIFAR family): stem conv → 3 stages of n basic
blocks at widths (16, 32, 64), strides (1, 2, 2) → global pool → FC head.

Quantization layout (paper §3.4.1):
  * stem conv and FC head are fixed at 8-bit (first/last-layer rule);
  * every block conv and downsample conv is selectable (2- or 4-bit);
  * a downsample conv is *linked* with the conv that feeds the same
    residual ReLU (paper Fig. 9 caption) — same link_group, one knapsack
    item;
  * GroupNorm keeps the network stateless (no BN running stats in the
    checkpoint).
"""

import jax
import jax.numpy as jnp

from .common import (conv_params, layer_entry, norm_params, group_norm,
                     qconv, linear_params)
from ..quantizer import quantize_act, quantize_weight
from .common import _safe


def make_config(depth=20, num_classes=10, image=32, width=(16, 32, 64)):
    assert (depth - 2) % 6 == 0, "depth must be 6n+2"
    return {
        "name": f"qresnet{depth}",
        "depth": depth,
        "n": (depth - 2) // 6,
        "num_classes": num_classes,
        "image": image,
        "width": list(width),
    }


def _block_names(cfg):
    """Yield (stage, block, conv_idx) for every block conv, in forward order."""
    for s in range(3):
        for b in range(cfg["n"]):
            yield s, b


def init_params(rng, cfg):
    n, w = cfg["n"], cfg["width"]
    keys = iter(jax.random.split(rng, 4 + 3 * n * 3))
    params = {"stem": conv_params(next(keys), 3, 3, 3, w[0], bits_init=8),
              "stem_norm": norm_params(w[0])}
    cin = w[0]
    for s, b in _block_names(cfg):
        cout = w[s]
        blk = {
            "conv1": conv_params(next(keys), 3, 3, cin, cout),
            "norm1": norm_params(cout),
            "conv2": conv_params(next(keys), 3, 3, cout, cout),
            "norm2": norm_params(cout),
        }
        if b == 0 and s > 0:
            blk["down"] = conv_params(next(keys), 1, 1, cin, cout)
        params[f"s{s}b{b}"] = blk
        cin = cout
    params["head"] = linear_params(next(keys), w[2], cfg["num_classes"], bits_init=8)
    return params


def layer_table(cfg):
    """Manifest rows, in qindex order (must match forward()'s bits indexing)."""
    img, w, n = cfg["image"], cfg["width"], cfg["n"]
    rows, qi = [], 0

    def push(name, kind, link, macs, wp, fixed=None, cin=None, cout=None):
        nonlocal qi
        rows.append(layer_entry(name, kind, qi, link, macs, wp, fixed, cin, cout))
        qi += 1

    push("stem", "conv", "stem", img * img * 3 * w[0] * 9, 3 * w[0] * 9,
         fixed=8, cin=3, cout=w[0])
    hw = img
    cin = w[0]
    for s in range(3):
        cout = w[s]
        for b in range(n):
            stride = 2 if (b == 0 and s > 0) else 1
            hw_out = hw // stride
            link2 = f"s{s}b{b}.out" if (b == 0 and s > 0) else f"s{s}b{b}.conv2"
            push(f"s{s}b{b}.conv1", "conv", f"s{s}b{b}.conv1",
                 hw_out * hw_out * cin * cout * 9, cin * cout * 9,
                 cin=cin, cout=cout)
            push(f"s{s}b{b}.conv2", "conv", link2,
                 hw_out * hw_out * cout * cout * 9, cout * cout * 9,
                 cin=cout, cout=cout)
            if b == 0 and s > 0:
                push(f"s{s}b{b}.down", "conv", link2,
                     hw_out * hw_out * cin * cout, cin * cout,
                     cin=cin, cout=cout)
            hw = hw_out
            cin = cout
    push("head", "linear", "head", w[2] * cfg["num_classes"],
         w[2] * cfg["num_classes"], fixed=8, cin=w[2], cout=cfg["num_classes"])
    return rows


def num_bits_entries(cfg):
    return len(layer_table(cfg))


def forward(params, x, bits, cfg):
    """x: (B, H, W, 3) f32 in [0,1]; returns (B, num_classes) logits."""
    qi = 0

    def nb():
        nonlocal qi
        b = bits[qi]
        qi += 1
        return b

    # Stem input is the raw image — signed=False fine ([0,1] range).
    h = qconv(params["stem"], x, nb(), 1)
    h = jax.nn.relu(group_norm(params["stem_norm"], h))
    for s in range(3):
        for b in range(cfg["n"]):
            blk = params[f"s{s}b{b}"]
            stride = 2 if (b == 0 and s > 0) else 1
            b1 = nb()
            y = qconv(blk["conv1"], h, b1, stride)
            y = jax.nn.relu(group_norm(blk["norm1"], y))
            b2 = nb()
            y = qconv(blk["conv2"], y, b2, 1)
            y = group_norm(blk["norm2"], y)
            if "down" in blk:
                bd = nb()
                sc = qconv(blk["down"], h, bd, stride)
            else:
                sc = h
            h = jax.nn.relu(y + sc)
    h = jnp.mean(h, axis=(1, 2))  # global average pool
    # 8-bit head: quantize pooled features + weights via the linear path.
    p = params["head"]
    bh = nb()
    sa, sw = _safe(p["sa"]), _safe(p["sw"])
    hq = quantize_act(h, sa, bh, signed=False)
    wq = quantize_weight(p["w"], sw, bh)
    return hq @ wq + p["b"]


def loss_and_metric(params, batch, bits, cfg):
    """Cross-entropy loss + batch accuracy. batch = (x, y_int32)."""
    x, y = batch
    logits = forward(params, x, bits, cfg)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return loss, acc


def eval_outputs(params, batch, bits, cfg):
    """(loss, correct_count) — Rust accumulates over eval batches."""
    x, y = batch
    logits = forward(params, x, bits, cfg)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return loss, correct
