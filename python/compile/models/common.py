"""Shared L2 building blocks: quantized conv / linear layers, norms, init.

Every quantizable layer reads its bit-width from a runtime ``bits`` vector
(one f32 entry per layer, indexed by the layer's ``qindex``), so one lowered
artifact serves every precision configuration the knapsack optimizer
produces.  Fixed-precision layers (stem / head at 8-bit, paper §3.4.1) go
through the same code path — the Rust coordinator simply pins their ``bits``
entries.
"""

import jax
import jax.numpy as jnp

from ..quantizer import quantize_act, quantize_weight, qrange, init_step_size
from ..kernels.quant_matmul import quant_matmul


def _safe(s):
    """Step sizes are learned; keep them strictly positive."""
    return jnp.abs(s) + 1e-8


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def qconv(p, x, bits_l, stride=1, signed_act=False):
    """LSQ-quantized 2-D convolution (NHWC · HWIO), SAME padding.

    Activations are quantized unsigned (post-ReLU inputs) unless
    ``signed_act``; weights signed symmetric.  Both at ``bits_l``
    (weights and input activations of a layer share precision, §3.4.1).
    """
    sa, sw = _safe(p["sa"]), _safe(p["sw"])
    xq = quantize_act(x, sa, bits_l, signed=signed_act)
    wq = quantize_weight(p["w"], sw, bits_l)
    y = jax.lax.conv_general_dilated(
        xq, wq,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


# When True, qlinear uses the pure-jnp LSQ path instead of the Pallas
# kernel.  Needed only while tracing vhv_step: grad-of-grad through the
# Pallas custom_vjp has no autodiff rule, and the two paths are numerically
# identical (pytest asserts allclose).  The train/eval hot paths always
# trace the Pallas kernel.
REF_LINEAR = False


def qlinear(p, x, bits_l):
    """LSQ-quantized linear layer through the L1 Pallas quant-matmul kernel.

    x: (..., d_in) — flattened to 2-D for the kernel's (M, K)·(K, N) grid.
    Transformer activations may be negative → signed range for both
    operands.
    """
    sa, sw = _safe(p["sa"]), _safe(p["sw"])
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    if REF_LINEAR:
        xq = quantize_act(x2, sa, bits_l, signed=True)
        wq = quantize_weight(p["w"], sw, bits_l)
        y = xq @ wq
    else:
        qna, qpa = qrange(bits_l, signed=True)
        qnw, qpw = qrange(bits_l, signed=True)
        y = quant_matmul(x2, p["w"], sa, sw, qna, qpa, qnw, qpw)
    return y.reshape(lead + (p["w"].shape[1],)) + p["b"]


def group_norm(p, x, groups=8, eps=1e-5):
    """GroupNorm over NHWC (stateless — no running stats to checkpoint)."""
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g != 0:
        g -= 1
    xg = x.reshape(b, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(b, h, w, c) * p["gamma"] + p["beta"]


def layer_norm(p, x, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * p["gamma"] + p["beta"]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def conv_params(rng, kh, kw, cin, cout, bits_init=4):
    """He-init conv weights + LSQ step sizes at the checkpoint precision."""
    w = jax.random.normal(rng, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / (kh * kw * cin))
    return {
        "w": w.astype(jnp.float32),
        "b": jnp.zeros((cout,), jnp.float32),
        "sw": jnp.asarray(init_step_size(w, bits_init), jnp.float32).reshape(()),
        "sa": jnp.asarray(0.35, jnp.float32),  # post-ReLU/GN range; learned
    }


def linear_params(rng, din, dout, bits_init=4):
    w = jax.random.normal(rng, (din, dout)) * jnp.sqrt(1.0 / din)
    return {
        "w": w.astype(jnp.float32),
        "b": jnp.zeros((dout,), jnp.float32),
        "sw": jnp.asarray(init_step_size(w, bits_init), jnp.float32).reshape(()),
        "sa": jnp.asarray(0.2, jnp.float32),
    }


def norm_params(c):
    return {"gamma": jnp.ones((c,), jnp.float32), "beta": jnp.zeros((c,), jnp.float32)}


# ---------------------------------------------------------------------------
# Layer table
# ---------------------------------------------------------------------------

def layer_entry(name, kind, qindex, link_group, macs, weight_params,
                fixed_bits=None, cin=None, cout=None):
    """One row of the manifest layer table the Rust graph module consumes."""
    return {
        "name": name,
        "kind": kind,
        "qindex": qindex,
        "link_group": link_group,
        "macs": int(macs),
        "weight_params": int(weight_params),
        "fixed_bits": fixed_bits,
        "cin": cin,
        "cout": cout,
    }
